"""Serving example: batched prefill + greedy decode against the KV/SSM cache.

  PYTHONPATH=src python examples/serve_lm.py --arch qwen3-0.6b --tokens 16
  PYTHONPATH=src python examples/serve_lm.py --arch mamba2-370m --tokens 16

Runs a batch of synthetic prompts through prefill, then decodes N tokens,
timing per-token latency — the serve_step lowered by the decode_* dry-run
shapes, at CPU demo size.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.models.model_zoo import build

    cfg = get_config(args.arch, smoke=True)
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(0)
    B, S = args.batch, args.prompt_len
    max_len = S + args.tokens + 1
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))}
    if cfg.family == "encdec":
        batch["enc_frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.enc_len, cfg.d_model)), jnp.float32)
    if cfg.family == "vlm":
        nv = min(cfg.n_vision_tokens, S)
        batch["vision_embeds"] = jnp.asarray(
            rng.standard_normal((B, nv, cfg.d_model)), jnp.float32)

    t0 = time.perf_counter()
    last, cache = jax.block_until_ready(api.prefill(params, batch, max_len))
    t_prefill = time.perf_counter() - t0
    print(f"{args.arch}: prefill {B}x{S} in {t_prefill*1e3:.0f} ms "
          f"({B*S/t_prefill:.0f} tok/s)")

    logits = jnp.einsum("bd,vd->bv", last, params["lm_head"])
    token = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [token]
    step = jax.jit(lambda p, t, c, k: api.decode_step(p, t, c, k),
                   static_argnums=3)
    t0 = time.perf_counter()
    for i in range(args.tokens):
        logits, cache = step(params, token, cache, S + i)
        token = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(token)
    jax.block_until_ready(token)
    dt = (time.perf_counter() - t0) / args.tokens
    print(f"decode: {dt*1e3:.1f} ms/token ({B/dt:.0f} tok/s batched)")
    print("generated token ids (seq 0):",
          [int(t[0]) for t in out][: args.tokens + 1])


if __name__ == "__main__":
    main()
