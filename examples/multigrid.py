"""Geometric multigrid vs single-level Jacobi — the same Table-1 Laplace
solve the paper runs on the wafer, but with the V-cycle built out of the
repo's own stencil plans (smoothers, restriction/prolongation and red-black
sweeps all dispatch through ``make_plan``).

Also solves a heterogeneous-diffusion problem: a per-cell conductivity field
``kappa`` turned into a variable-coefficient stencil
(``heterogeneous_jacobi``) whose taps carry grid-shaped weight fields — the
same spec runs through the dense / conv-gather / Pallas encodings.

  PYTHONPATH=src python examples/multigrid.py
"""
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.core import (
    heterogeneous_jacobi,
    laplace_jacobi,
    multigrid_solve,
    solve,
    stencil_apply,
)


def main():
    grid = (64, 64)
    bc_value = 1.0
    spec = laplace_jacobi(2)
    x0 = jnp.zeros(grid, jnp.float32)

    print(f"== Laplace on {grid}, walls at {bc_value} ==")
    jac = solve(spec, x0, bc=bc_value, rtol=1e-6, check_every=20,
                max_iters=20_000)
    print(f"jacobi:    {jac.iterations} iterations "
          f"(residual {jac.residual:.1e}, backend {jac.backend})")

    mg = multigrid_solve(spec, x0, bc=bc_value, rtol=1e-6)
    print(f"multigrid: {mg.cycles} V-cycles = {mg.work_units:.0f} fine-grid "
          f"work units (residual {mg.residual:.1e}, levels "
          f"{'->'.join(str(s[0]) for s in mg.level_shapes)}, smoother "
          f"red-black)")
    err = float(jnp.abs(mg.x - jac.x).max())
    ratio = jac.iterations / mg.work_units
    print(f"agreement |mg - jacobi|_max = {err:.1e}; multigrid did "
          f"{ratio:.0f}x less fine-grid work\n")

    # Variable-coefficient diffusion: a conductive inclusion in a slab.
    n = 65
    kappa = np.ones((n, n), np.float32)
    kappa[20:45, 20:45] = 10.0  # 10x more conductive block in the middle
    hspec = heterogeneous_jacobi(kappa)
    print(f"== heterogeneous diffusion on ({n}, {n}), kappa in "
          f"[{kappa.min():.0f}, {kappa.max():.0f}] ==")
    # The spec's taps are per-cell weight fields; every supported backend
    # computes the same operator (cross-validated in tests/conformance/).
    x = jnp.asarray(np.random.default_rng(0).standard_normal((n, n)),
                    jnp.float32)
    ref = stencil_apply(hspec, x, backend="reference", bc=bc_value)
    for backend in ("dense", "conv", "pallas"):
        from repro.core import BoundaryMode, backend_support
        mode = (BoundaryMode.MATRIX if backend == "dense"
                else BoundaryMode.MASK)
        out = stencil_apply(hspec, x, backend=backend, mode=mode, bc=bc_value)
        print(f"{backend:8s} err={float(jnp.abs(out - ref).max()):.2e}")

    hres = multigrid_solve(hspec, jnp.zeros((n, n), jnp.float32),
                           bc=bc_value, rtol=1e-6)
    print(f"multigrid: converged={hres.converged} in {hres.cycles} V-cycles "
          f"({hres.work_units:.0f} work units, residual {hres.residual:.1e})")


if __name__ == "__main__":
    main()
