"""End-to-end LM training driver: data pipeline -> sharded train step ->
fault-tolerant runtime with checkpointing.

  PYTHONPATH=src python examples/train_lm.py                 # CPU demo (~8M params, 200 steps)
  PYTHONPATH=src python examples/train_lm.py --full          # ~100M config (needs accelerator time)
  PYTHONPATH=src python examples/train_lm.py --arch mamba2-370m  # any zoo arch (smoke size)

Demonstrates: loss descending on the synthetic stream, checkpoint/restart
(kill it mid-run and re-invoke — it resumes), straggler flagging.
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--full", action="store_true",
                    help="~100M-param config instead of the CPU demo size")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--checkpoint-dir", default="artifacts/train_lm_ckpt")
    args = ap.parse_args()

    from repro.launch.train import main as train_main

    argv = ["--arch", args.arch, "--smoke",
            "--steps", str(args.steps),
            "--global-batch", str(args.global_batch),
            "--seq-len", str(args.seq_len),
            "--checkpoint-dir", args.checkpoint_dir,
            "--checkpoint-every", "50",
            "--log-every", "10"]
    if args.full:
        # ~100M: override the smoke config in-place via a registered variant
        import repro.configs.base as B
        from repro.configs import get_config
        base = get_config(args.arch, smoke=True)
        cfg100 = dataclasses.replace(
            base, arch=base.arch + "-100m", n_layers=12, d_model=768,
            n_heads=12, n_kv_heads=4, head_dim=64, d_ff=2048,
            vocab_size=32768)
        B.register(base.arch + "-100m", lambda: cfg100, lambda: cfg100)
        argv = ["--arch", base.arch + "-100m"] + argv[2:]
    return train_main(argv)


if __name__ == "__main__":
    sys.exit(main())
