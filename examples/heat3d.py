"""3D heat diffusion with non-zero Dirichlet boundary conditions — the paper's
Fig 6 scenario (X=64, Y=64, Z=10) through the channels-trick Conv2D encoding
and the native paths the CS-1 could not express; optionally distributed over
a device grid with halo exchange.

  PYTHONPATH=src python examples/heat3d.py [--distributed]

(--distributed needs >1 jax device; run under
 XLA_FLAGS=--xla_force_host_platform_device_count=8 to try it on CPU.)
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    DirichletBC,
    jacobi_reference,
    laplace_jacobi,
    stencil_apply,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--iters", type=int, default=50)
    args = ap.parse_args()

    spec = laplace_jacobi(3)
    bc_value = 100.0  # hot walls
    bc = DirichletBC(bc_value)
    grid = (10, 64, 64)
    rng = np.random.default_rng(0)
    x0 = jnp.zeros((1, *grid), jnp.float32)

    print(f"== 3D heat, grid (Z,X,Y)={grid}, walls at {bc_value} ==")
    ref = jnp.stack([jacobi_reference(x0[0], spec, bc, args.iters)])

    # One spec, three encodings — all through the unified dispatcher.
    ch = stencil_apply(spec, x0, backend="conv", bc=bc_value, iters=args.iters)
    nat = stencil_apply(spec, x0, backend="conv3d_native", bc=bc_value,
                        iters=args.iters)
    ker = stencil_apply(spec, x0, backend="pallas", bc=bc_value,
                        iters=args.iters)
    auto = stencil_apply(spec, x0, backend="auto", bc=bc_value,
                         iters=args.iters)
    print(f"channels-trick  err={float(jnp.abs(ch - ref).max()):.2e}")
    print(f"native conv3d   err={float(jnp.abs(nat - ref).max()):.2e}")
    print(f"pallas direct   err={float(jnp.abs(ker - ref).max()):.2e}")
    print(f"auto            err={float(jnp.abs(auto - ref).max()):.2e}")
    centre = ch[0, grid[0] // 2, grid[1] // 2, grid[2] // 2]
    print(f"centre temperature after {args.iters} iters: {float(centre):.3f} "
          f"(walls {bc_value}) — heat diffusing inward ✓")

    if args.distributed:
        n = len(jax.devices())
        if n < 2:
            print("(--distributed skipped: single device)")
            return
        # distribute the 2D X-Y plane of the mid-Z slice problem
        mesh = jax.make_mesh((2, n // 2), ("data", "model"))
        spec2 = laplace_jacobi(2)
        x2 = jnp.zeros((2, 64, 64), jnp.float32)
        out = stencil_apply(spec2, x2, backend="halo", bc=bc_value,
                            iters=args.iters, mesh=mesh)
        ref2 = jnp.stack([jacobi_reference(x2[i], spec2, DirichletBC(bc_value),
                                           args.iters) for i in range(2)])
        print(f"distributed halo-exchange (mesh {dict(mesh.shape)}) "
              f"err={float(jnp.abs(out - ref2).max()):.2e}")


if __name__ == "__main__":
    main()
