"""3D heat diffusion with non-zero Dirichlet boundary conditions — the paper's
Fig 6 scenario (X=64, Y=64, Z=10) through the channels-trick Conv2D encoding
and the native paths the CS-1 could not express, run to convergence through
the ``solve`` engine; optionally distributed over a device grid with halo
exchange (same ``solve()`` entry point, ``backend="halo"``).

  PYTHONPATH=src python examples/heat3d.py [--distributed]

(--distributed needs >1 jax device; run under
 XLA_FLAGS=--xla_force_host_platform_device_count=8 to try it on CPU.)
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.core import laplace_jacobi, solve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--iters", type=int, default=50)
    args = ap.parse_args()

    spec = laplace_jacobi(3)
    bc_value = 100.0  # hot walls
    grid = (10, 64, 64)
    x0 = jnp.zeros(grid, jnp.float32)

    print(f"== 3D heat, grid (Z,X,Y)={grid}, walls at {bc_value} ==")
    # One spec, three encodings — all through the unified solver engine
    # (fixed-iteration mode), cross-validated against the oracle backend.
    ref = solve(spec, x0, backend="reference", bc=bc_value,
                rtol=None, atol=None, max_iters=args.iters).x
    for backend in ("conv", "conv3d_native", "pallas", "auto"):
        res = solve(spec, x0, backend=backend, bc=bc_value,
                    rtol=None, atol=None, max_iters=args.iters)
        tag = f"auto -> {res.backend}" if backend == "auto" else backend
        print(f"{tag:22s} err={float(jnp.abs(res.x - ref).max()):.2e}")

    # the actual experiment: iterate until the walls' heat fills the slab
    res = solve(spec, x0, backend="auto", bc=bc_value,
                rtol=1e-6, check_every=20, max_iters=20_000)
    centre = res.x[grid[0] // 2, grid[1] // 2, grid[2] // 2]
    print(f"solve: converged={res.converged} after {res.iterations} iters "
          f"(residual {res.residual:.1e}, backend {res.backend}); centre "
          f"temperature {float(centre):.3f} (walls {bc_value}) — heat "
          f"diffused inward ✓")

    if args.distributed:
        n = len(jax.devices())
        if n < 2:
            print("(--distributed skipped: single device)")
            return
        # distribute the 2D X-Y plane of the mid-Z slice problem over the
        # device mesh — the identical solve() call, backend="halo"
        mesh = jax.make_mesh((2, n // 2), ("data", "model"))
        spec2 = laplace_jacobi(2)
        x2 = jnp.zeros((2, 64, 64), jnp.float32)
        dist = solve(spec2, x2, backend="halo", mesh=mesh, bc=bc_value,
                     rtol=1e-6, check_every=20, max_iters=20_000)
        single = solve(spec2, x2, backend="reference", bc=bc_value,
                       rtol=1e-6, check_every=20, max_iters=20_000)
        err = float(jnp.abs(dist.x - single.x).max())
        print(f"distributed halo-exchange solve (mesh {dict(mesh.shape)}): "
              f"iters={list(map(int, dist.iterations))} vs single-device "
              f"{list(map(int, single.iterations))}, field err={err:.2e}")


if __name__ == "__main__":
    main()
