"""Learn a stencil from steady states — the adjoint solve as a layer.

Inverse problem: a hidden heterogeneous conductivity field ``kappa`` defines
a diffusion operator; we observe (source, steady-state) pairs produced by
solving it, and recover the operator by gradient descent *through the
solver*.  The forward pass is ``implicit_solve`` run to convergence; the
backward pass is one adjoint solve with the transposed stencil (O(1) memory
in iteration count — see src/repro/core/adjoint.py), so the whole thing
trains under the repo's standard ``make_train_step`` + AdamW stack, with a
checkpoint round-trip mid-run to prove solver state restores exactly.

  PYTHONPATH=src python examples/learned_stencil.py            # full run
  PYTHONPATH=src python examples/learned_stencil.py --smoke \
      --steps 20 --assert-decreasing                           # CI smoke
"""
import argparse
import sys
import tempfile

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np


def make_dataset(cfg, n_batches, batch, seed=0):
    """(source, target) pairs from a hidden ground-truth operator."""
    from repro.core import heterogeneous_jacobi, implicit_solve

    rng = np.random.default_rng(seed)
    kappa = 1.0 + 9.0 * rng.random(cfg.grid)
    true_spec = heterogeneous_jacobi(kappa, name="hidden-kappa")
    true_fields = jnp.asarray(true_spec.field_stack())
    data = []
    for _ in range(n_batches):
        src = jnp.asarray(rng.standard_normal((batch, *cfg.grid)), jnp.float32)
        tgt = implicit_solve(
            true_spec, jnp.zeros_like(src), fields=true_fields, source=src,
            backend=cfg.backend, rtol=1e-6, max_iters=2 * cfg.max_iters)
        data.append({"source": src, "target": tgt})
    return data, true_fields


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small grid / few iterations (CPU CI)")
    ap.add_argument("--steps", type=int, default=250)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--assert-decreasing", action="store_true",
                    help="exit nonzero unless loss drops >= 10x")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.checkpoint.checkpoint import Checkpointer
    from repro.models.model_zoo import build
    from repro.optim.adamw import AdamWConfig
    from repro.train.train_step import init_train_state, make_train_step

    cfg = get_config("learned-stencil", smoke=args.smoke)
    api = build(cfg)
    print(f"== learned-stencil on {cfg.grid}, backend={cfg.backend}, "
          f"{args.steps} steps ==")

    # Full-batch training: the inverse problem is deterministic, and batch
    # rotation only adds optimizer churn that short runs cannot average out.
    data, true_fields = make_dataset(cfg, n_batches=1, batch=args.batch)
    state = init_train_state(api, jax.random.PRNGKey(0))
    opt = AdamWConfig(lr=args.lr, warmup_steps=10, total_steps=args.steps,
                      weight_decay=0.0, grad_clip=1.0)
    step = jax.jit(make_train_step(api, None, opt))

    # The 10x criterion is judged on one fixed batch — per-step train losses
    # come from rotating batches and are not comparable to each other.
    from repro.models.solver_layer import solver_loss_fn
    eval_loss = jax.jit(
        lambda params: solver_loss_fn(api, params, data[0])[0])
    first = float(eval_loss(state["params"]))
    ckpt_at = max(1, args.steps // 2)
    with tempfile.TemporaryDirectory() as ckdir:
        ck = Checkpointer(ckdir, keep=2)
        for i in range(args.steps):
            state, metrics = step(state, data[i % len(data)])
            loss = float(metrics["loss"])
            if i % max(1, args.steps // 10) == 0 or i == args.steps - 1:
                print(f"step {i:4d}  loss {loss:.3e}  "
                      f"lr {float(metrics['lr']):.2e}  "
                      f"|g| {float(metrics['grad_norm']):.2e}")
            if i + 1 == ckpt_at:
                # Round-trip the full train state through a checkpoint and
                # keep training from the restored copy — the restored solve
                # must continue bit-for-bit.
                ck.save(i + 1, state)
                _, restored = ck.restore_latest()
                before = step(state, data[0])[1]["loss"]
                after = step(restored, data[0])[1]["loss"]
                assert float(before) == float(after), (before, after)
                state = restored
                print(f"step {i+1:4d}  checkpoint round-trip OK "
                      f"(loss identical: {float(after):.3e})")

    last = float(eval_loss(state["params"]))
    taps = state["params"]["taps"]
    tap_err = float(jnp.abs(taps - true_fields).mean())
    print(f"eval loss {last:.3e} ({first / max(last, 1e-30):.0f}x down "
          f"from {first:.3e}); mean |taps - true| = {tap_err:.3f}")
    if args.assert_decreasing and not last <= first / 10.0:
        print("FAIL: loss did not decrease 10x", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
