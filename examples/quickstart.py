"""Quickstart: the paper's 2D Jacobi benchmark through every encoding.

  PYTHONPATH=src python examples/quickstart.py

Builds a 64x64 Laplace problem with Dirichlet BC = 1.0 (paper Table 1 shape),
solves it with (a) the dense-layer encoding, (b) the convolution encoding
with the mask trick, (c) the direct Pallas stencil kernel, (d) the
temporally-blocked fused kernel — and cross-validates that all four agree
with the reference oracle, then reports the paper's delivered-performance
metric for each.
"""
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import (
    BoundaryMode,
    DeliveredPerf,
    DirichletBC,
    conv_jacobi_2d,
    dense_jacobi_with_bc,
    encoding_flops_per_point,
    jacobi_reference,
    laplace_jacobi,
)
from repro.kernels import jacobi2d
from benchmarks.common import time_callable


def main():
    spec = laplace_jacobi(2)
    bc = DirichletBC(1.0)
    grid = (64, 64)
    iters = 20
    steps = 4
    rng = np.random.default_rng(0)
    x0 = jnp.asarray(rng.standard_normal((steps, *grid)), jnp.float32)

    print(f"== 2D Jacobi, grid {grid}, {iters} iterations, BC=1.0 ==")
    ref = jnp.stack([jacobi_reference(x0[i], spec, bc, iters)
                     for i in range(steps)])

    runs = {
        "dense-layer (Alg 1)": lambda: dense_jacobi_with_bc(x0, spec, bc, iters),
        "conv-layer (Alg 2, mask trick)": lambda: conv_jacobi_2d(
            x0, spec, bc, iters, BoundaryMode.MASK),
        "conv-layer (pad mode)": lambda: conv_jacobi_2d(
            x0, spec, bc, iters, BoundaryMode.PAD),
        "pallas direct": lambda: jacobi2d(x0, spec, bc_value=1.0,
                                          iterations=iters, block_h=64),
        "pallas fused T=4": lambda: jacobi2d(x0, spec, bc_value=1.0,
                                             iterations=iters, fuse=4,
                                             block_h=64),
    }
    flops = {
        "dense-layer (Alg 1)": encoding_flops_per_point(spec, "dense", 4096),
        "conv-layer (Alg 2, mask trick)": encoding_flops_per_point(spec, "conv"),
        "conv-layer (pad mode)": encoding_flops_per_point(spec, "conv"),
        "pallas direct": encoding_flops_per_point(spec, "direct"),
        "pallas fused T=4": encoding_flops_per_point(spec, "direct"),
    }
    n = grid[0] * grid[1]
    for name, fn in runs.items():
        out = fn()
        err = float(jnp.abs(out - ref).max())
        sec = time_callable(lambda: fn(), warmup=1, iters=1)
        perf = DeliveredPerf(n * steps, flops[name], 7, iters, sec)
        print(f"{name:32s} max|err|={err:.2e}  "
              f"delivered={perf.delivered_gflops:8.3f} GFLOPS  "
              f"useful={perf.useful_gflops:7.3f}  waste x{perf.waste_ratio:.1f}")
    print("\nall encodings agree with the reference oracle ✓")


if __name__ == "__main__":
    main()
