"""Quickstart: the paper's 2D Jacobi benchmark through every encoding, all
dispatched through the unified ``stencil_apply`` / ``make_plan`` API, then
run to convergence through the ``solve`` engine.

  PYTHONPATH=src python examples/quickstart.py

Builds a 64x64 Laplace problem with Dirichlet BC = 1.0 (paper Table 1 shape),
lowers it through (a) the dense-layer encoding, (b) the convolution encoding
with the mask trick, (c) the direct Pallas stencil kernel, (d) the
temporally-blocked fused kernel, (e) whatever the auto cost model picks —
cross-validates that all agree with the reference oracle, reports the
paper's delivered-performance metric for each, and finally runs the actual
experiment: iterate until the relative residual converges, the whole time
loop as one compiled program (no manual Python iteration loop).
"""
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import (
    BoundaryMode,
    DeliveredPerf,
    encoding_flops_per_point,
    laplace_jacobi,
    make_plan,
    solve,
)
from benchmarks.common import time_callable


def main():
    spec = laplace_jacobi(2)
    grid = (64, 64)
    iters = 20
    steps = 4
    rng = np.random.default_rng(0)
    x0 = jnp.asarray(rng.standard_normal((steps, *grid)), jnp.float32)

    print(f"== 2D Jacobi, grid {grid}, {iters} iterations, BC=1.0 ==")
    # the oracle, via the same solver engine (fixed-iteration mode) instead
    # of a manual per-instance Python loop
    ref = solve(spec, x0, backend="reference", bc=1.0,
                rtol=None, atol=None, max_iters=iters).x

    plans = {
        "dense-layer (Alg 1)": make_plan(
            spec, grid, backend="dense", bc=1.0, mode=BoundaryMode.MATRIX,
            iters=iters),
        "conv-layer (Alg 2, mask trick)": make_plan(
            spec, grid, backend="conv", bc=1.0, mode=BoundaryMode.MASK,
            iters=iters),
        "conv-layer (pad mode)": make_plan(
            spec, grid, backend="conv", bc=1.0, mode=BoundaryMode.PAD,
            iters=iters),
        "pallas direct": make_plan(
            spec, grid, backend="pallas", bc=1.0, iters=iters),
        "pallas fused T=4": make_plan(
            spec, grid, backend="pallas_fused", bc=1.0, iters=iters, fuse=4),
    }
    auto = make_plan(spec, grid, backend="auto", bc=1.0, iters=iters)
    plans[f"auto -> {auto.backend}"] = auto

    n = grid[0] * grid[1]
    for name, plan in plans.items():
        if plan.backend == "dense":
            flops = encoding_flops_per_point(spec, "dense", n_total=n)
        elif plan.backend in ("conv", "conv3d_native"):
            flops = encoding_flops_per_point(spec, "conv")
        else:
            flops = encoding_flops_per_point(spec, "direct")
        out = plan(x0)
        err = float(jnp.abs(out - ref).max())
        sec = time_callable(plan, x0, warmup=1, iters=1)
        perf = DeliveredPerf(n * steps, flops, 7, iters, sec)
        print(f"{name:32s} max|err|={err:.2e}  "
              f"delivered={perf.delivered_gflops:8.3f} GFLOPS  "
              f"useful={perf.useful_gflops:7.3f}  waste x{perf.waste_ratio:.1f}")
    print("\nall encodings agree with the reference oracle ✓")

    # The paper's actual experiment is a *solve*: iterate until the residual
    # converges.  No manual loop — the solver runs the whole time loop
    # on-device, checking the relative L2 residual every 20 iterations.
    print("\n== run to convergence (solve) ==")
    res = solve(spec, jnp.zeros(grid, jnp.float32), bc=1.0,
                rtol=1e-6, check_every=20, max_iters=20_000)
    print(f"auto -> {res.backend}: converged={res.converged} in "
          f"{res.iterations} iterations  (residual {res.residual:.2e}, "
          f"{res.wall_seconds:.2f}s wall, "
          f"{res.wall_seconds / res.iterations * 1e6:.0f} us/iter)")
    print(f"residual trajectory (every {res.check_every * 10} iters): "
          + " ".join(f"{r:.1e}" for r in res.residual_history[::10]))


if __name__ == "__main__":
    main()
