"""Deterministic synthetic data pipeline, shard-aware.

Batches are generated from a counter-based PRNG keyed on (seed, step,
host slice) so every host materializes only its slice and a restarted run
(possibly on a different host count) reproduces the identical global batch —
the property the elastic checkpoint/restart tests assert.

For the stencil side, ``stencil_tiles`` streams the paper's step-tiles with
overlapping boundary columns (paper §3: "overlapping is undertaken to ensure
boundary neighbours from one tile are available to another").
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234


def host_slice(global_batch: int, n_hosts: int, host_id: int) -> tuple[int, int]:
    per = global_batch // n_hosts
    return host_id * per, per


def token_batch(cfg: DataConfig, step: int, n_hosts: int = 1, host_id: int = 0):
    """Returns {tokens, labels} for this host's slice of the global batch."""
    start, per = host_slice(cfg.global_batch, n_hosts, host_id)
    rows = []
    for b in range(start, start + per):
        rng = np.random.Generator(np.random.Philox(key=cfg.seed + step * 1_000_003 + b))
        rows.append(rng.integers(0, cfg.vocab_size, cfg.seq_len + 1, dtype=np.int32))
    arr = np.stack(rows)
    return {"tokens": jnp.asarray(arr[:, :-1]), "labels": jnp.asarray(arr[:, 1:])}


def batches(cfg: DataConfig, n_steps: int, n_hosts: int = 1,
            host_id: int = 0) -> Iterator[dict]:
    for step in range(n_steps):
        yield token_batch(cfg, step, n_hosts, host_id)


def stencil_tiles(grid: tuple[int, ...], n_steps: int, seed: int = 0,
                  batch: int = 1) -> Iterator[jnp.ndarray]:
    """Stream of per-step stencil tiles (the paper's N-per-step decomposition)."""
    for step in range(n_steps):
        rng = np.random.Generator(np.random.Philox(key=seed + step))
        yield jnp.asarray(rng.standard_normal((batch, *grid)), jnp.float32)
