"""GQA attention: q-chunked flash-style softmax (train/prefill) + cached decode.

Two layouts, chosen by the sharding profile:

  * tp (shard_heads=True) — K/V are broadcast from KV to H heads *after* a
    sharding constraint on H, so the per-device score block is
    (B, H/tp, chunk, S): the broadcast is free post-partitioning (each
    device materializes only its head slice) and scores shard over the
    model axis.  GQA grouped einsums would instead leave scores replicated
    whenever KV < tp (e.g. 8 KV heads on a 16-wide axis) — that cost
    22.8 GB/device on the first dry-run of qwen3-0.6b (EXPERIMENTS §Perf).
  * sp/unsharded (shard_heads=False) — grouped einsum, no KV broadcast; the
    q sequence dim carries the sharding instead.

Scores never materialize at (S, S): a lax.scan over query chunks keeps the
live buffer at (B, H, chunk, S) fp32 and the rematted chunk body makes the
backward recompute probabilities per chunk.

Decode attends one query token against a sequence-sharded KV cache; the
softmax reduction over the sharded seq dim makes XLA SPMD emit the
flash-decoding combine (partial max/sum + small all-reduces) automatically.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

MASK_VALUE = -1e30


def _repeat_kv(k: jnp.ndarray, H: int) -> jnp.ndarray:
    """(B, S, KV, hd) -> (B, S, H, hd) broadcasting each KV head to its group."""
    B, S, KV, hd = k.shape
    G = H // KV
    return jnp.broadcast_to(k[:, :, :, None], (B, S, KV, G, hd)).reshape(B, S, H, hd)


def _chunk_attn_full(q, k, v, q_pos0, kv_pos, causal, scale):
    """q: (B,C,H,hd); k/v: (B,S,H,hd) (already head-broadcast)."""
    C = q.shape[1]
    scores = jnp.einsum("bchd,bshd->bhcs", q, k,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        qp = q_pos0 + jnp.arange(C)[:, None]
        mask = kv_pos[None, :] <= qp
        scores = jnp.where(mask[None, None], scores, MASK_VALUE)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhcs,bshd->bchd", probs.astype(v.dtype), v)
    return out.astype(q.dtype)


def _chunk_attn_grouped(q, k, v, q_pos0, kv_pos, causal, scale):
    """q: (B,C,KV,G,hd); k/v: (B,S,KV,hd) (no broadcast materialized)."""
    C = q.shape[1]
    scores = jnp.einsum("bckgh,bskh->bkgcs", q, k,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        qp = q_pos0 + jnp.arange(C)[:, None]
        mask = kv_pos[None, :] <= qp
        scores = jnp.where(mask[None, None, None], scores, MASK_VALUE)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgcs,bskh->bckgh", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    q_chunk: int = 1024,
    kv_offset: int = 0,
    shard_heads: bool = False,
) -> jnp.ndarray:
    """q: (B, Sq, H, hd); k/v: (B, Skv, KV, hd) -> (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    scale = hd ** -0.5
    kv_pos = jnp.arange(k.shape[1]) + kv_offset

    if shard_heads:
        k = _repeat_kv(k, H)
        v = _repeat_kv(v, H)
        qx = q
        chunk_fn = _chunk_attn_full
    else:
        qx = q.reshape(B, Sq, KV, H // KV, hd)
        chunk_fn = _chunk_attn_grouped

    if Sq % q_chunk:
        q_chunk = next(c for c in range(min(q_chunk, Sq), 0, -1) if Sq % c == 0)
    n_chunks = Sq // q_chunk

    if n_chunks == 1:
        out = chunk_fn(qx, k, v, 0, kv_pos, causal, scale)
        return out.reshape(B, Sq, H, hd)

    qc = qx.reshape(B, n_chunks, q_chunk, *qx.shape[2:])

    body = jax.checkpoint(
        lambda carry, inp: (
            carry,
            chunk_fn(inp[0], k, v, inp[1], kv_pos, causal, scale),
        )
    )
    xs = (jnp.moveaxis(qc, 1, 0), jnp.arange(n_chunks) * q_chunk)
    _, out = jax.lax.scan(body, 0, xs)
    out = jnp.moveaxis(out, 0, 1).reshape(B, Sq, H, hd)
    return out


def decode_attention(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    kv_len: jnp.ndarray | int,
) -> jnp.ndarray:
    """Single-step decode.  q: (B, 1, H, hd); caches: (B, S_max, KV, hd).

    Grouped einsum (no KV broadcast: decode is cache-bandwidth-bound).
    kv_len masks the valid prefix (cache slots >= kv_len are ignored).
    """
    B, _, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    scale = hd ** -0.5
    qg = q.reshape(B, KV, G, hd)
    scores = jnp.einsum(
        "bkgh,bskh->bkgs", qg, k_cache, preferred_element_type=jnp.float32
    ) * scale
    pos = jnp.arange(k_cache.shape[1])
    scores = jnp.where(pos[None, None, None, :] < kv_len, scores, MASK_VALUE)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bkgs,bskh->bkgh", probs.astype(v_cache.dtype), v_cache,
    ).astype(q.dtype)
    return out.reshape(B, 1, H, hd)
