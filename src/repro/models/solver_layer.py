"""Learned-stencil solver layer: the differentiable solve as a model family.

The bridge between the stencil core and the training stack (ISSUE 9
tentpole, layer 3): a ``ModelApi``-shaped wrapper whose "forward pass" runs
``core.adjoint.implicit_solve`` to convergence and whose parameters are the
stencil itself — a (V, *grid) stack of per-cell tap weights plus a scalar
Dirichlet boundary value.  Gradients flow through the converged fixed point
via the adjoint solve (O(1) memory in the iteration count), so the layer
trains under the *same* ``make_train_step`` / AdamW / Sharder / Checkpointer
machinery as the LM architectures.

The batch contract is ``{"source": (B, *grid), "target": (B, *grid)}`` —
learn the operator (e.g. a heterogeneous-diffusion kappa field) whose
steady states match observed solutions.  The loss is plain MSE against the
target steady state; ``train_step.make_train_step`` auto-dispatches to
:func:`solver_loss_fn` when ``api.cfg.family == "solver"``.

A solver layer computes in float32 regardless of the session compute dtype:
fixed-point convergence thresholds are meaningless in bf16, and the whole
parameter tree is a few grids, not a transformer.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.adjoint import DIFF_BACKENDS, implicit_solve
from repro.core.stencil import StencilSpec, heterogeneous_jacobi
from repro.models.layers import ParamDef


@dataclasses.dataclass(frozen=True)
class SolverLayerConfig:
    """Duck-typed stand-in for ``ModelConfig`` (family="solver").

    Carries only what the training stack actually reads off ``api.cfg``
    (arch / family / sharding_profile / source) plus the solve settings.
    """

    arch: str = "learned-stencil"
    family: str = "solver"
    grid: tuple[int, ...] = (32, 32)
    backend: str = "conv"              # must be in DIFF_BACKENDS
    rtol: float | None = 1e-5
    atol: float | None = 0.0
    max_iters: int = 500
    check_every: int | None = None
    init_weight: float = 0.25          # uniform-diffusion start (2D: 4 × 0.25)
    sharding_profile: str = "tp"
    source: str = "ISSUE 9: adjoint solve as a trainable layer"

    def __post_init__(self):
        if self.backend not in DIFF_BACKENDS:
            raise ValueError(
                f"solver layer needs a differentiable backend "
                f"{DIFF_BACKENDS}, got {self.backend!r}")
        if len(self.grid) < 1:
            raise ValueError("solver layer needs a non-empty grid shape")

    @property
    def is_causal_lm(self) -> bool:
        return False


def template_spec(cfg: SolverLayerConfig) -> StencilSpec:
    """The static spec the solve traces through.

    A uniform heterogeneous-Jacobi spec: every face tap is a per-cell
    ``WeightField``, so the plan streams all V taps as one runtime operand
    and the baked values are never read once ``fields=`` is passed.
    """
    return heterogeneous_jacobi(np.ones(cfg.grid), name="learned-stencil")


def _grid_dims(cfg: SolverLayerConfig) -> tuple[str, ...]:
    # Row dim shards over data (the only grid dim with a rule); the rest
    # replicate.  Names match _TP_RULES additions in parallel/sharding.py.
    names = ("grid_row", "grid_col", "grid_depth")
    return names[: len(cfg.grid)]


def solver_table(cfg: SolverLayerConfig) -> dict:
    spec = template_spec(cfg)
    V = spec.num_variable_taps
    return {
        "taps": ParamDef(
            (V, *cfg.grid),
            ("taps", *_grid_dims(cfg)),
            scale=f"const:{cfg.init_weight}",
            dtype=jnp.float32,
        ),
        "bc": ParamDef((), (), scale="zero", dtype=jnp.float32),
    }


def solver_forward(cfg: SolverLayerConfig, params, batch, sharder=None):
    """(B, *grid) source -> converged steady state, differentiably.

    ``params["taps"]`` rides into the solve as the runtime fields operand;
    ``params["bc"]`` as the Dirichlet value.  The solve starts from zeros —
    the fixed point forgets x0 anyway (its gradient is exactly zero), so
    there is nothing to learn about the initialisation.
    """
    spec = template_spec(cfg)
    source = jnp.asarray(batch["source"], jnp.float32)
    taps = params["taps"].astype(jnp.float32)
    bc = params["bc"].astype(jnp.float32)
    if sharder is not None:
        source = sharder.constrain(source, ("batch", *_grid_dims(cfg)))
    x0 = jnp.zeros_like(source)
    sol = implicit_solve(
        spec, x0, fields=taps, source=source, bc_value=bc,
        backend=cfg.backend, rtol=cfg.rtol, atol=cfg.atol,
        check_every=cfg.check_every, max_iters=cfg.max_iters)
    return sol, jnp.zeros((), jnp.float32)


def solver_loss_fn(api, params_f32, batch, sharder=None,
                   compute_dtype=jnp.float32):
    """MSE against the target steady state (the solver-family loss).

    Signature-compatible with ``train_step.loss_fn``; ``compute_dtype`` is
    accepted but the solve always runs float32 (see module docstring).
    """
    del compute_dtype
    pred, aux = api.forward(params_f32, batch, sharder=sharder)
    err = pred - jnp.asarray(batch["target"], jnp.float32)
    mse = jnp.mean(jnp.square(err))
    return mse, {"mse": mse, "aux": aux}


def _unsupported(what: str):
    def fn(*a, **k):
        raise NotImplementedError(
            f"solver layers have no {what} — they map source fields to "
            f"steady states, not token streams")
    return fn


def build_solver_api(cfg: SolverLayerConfig):
    """ModelApi for the solver family (called from ``model_zoo.build``)."""
    from repro.models.layers import init_params, param_dims, param_shapes
    from repro.models.model_zoo import ModelApi

    table = solver_table(cfg)

    def forward(params, batch, sharder=None):
        return solver_forward(cfg, params, batch, sharder=sharder)

    return ModelApi(
        cfg=cfg,
        table=table,
        init=lambda key, dtype=jnp.float32: init_params(table, key, dtype),
        shapes=lambda dtype=jnp.float32: param_shapes(table, dtype),
        dims=lambda: param_dims(table),
        forward=forward,
        prefill=_unsupported("prefill"),
        decode_step=_unsupported("decode step"),
        cache_shapes=lambda *a, **k: {},
        cache_dims=lambda: {},
    )
