"""Family-dispatched model API: one namespace the train/serve/launch layers use.

  build(cfg)          -> ModelApi with init/shapes/dims/forward/prefill/decode
  All functions are functional (params in, arrays out) for pjit friendliness.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec as _encdec
from repro.models import transformer as _tf
from repro.models.layers import init_params, param_dims, param_shapes


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: ModelConfig
    table: dict
    init: Callable[..., Any]
    shapes: Callable[..., Any]
    dims: Callable[[], Any]
    forward: Callable[..., Any]          # train-mode: -> (hidden, aux)
    prefill: Callable[..., Any]          # -> (last hidden/logits, cache)
    decode_step: Callable[..., Any]      # -> (logits, cache)
    cache_shapes: Callable[..., Any]
    cache_dims: Callable[[], Any]


def build(cfg: ModelConfig) -> ModelApi:
    if cfg.family == "solver":
        # Learned-stencil layer: forward = a differentiable fixed-point
        # solve; params = the stencil weights (see models/solver_layer.py).
        from repro.models.solver_layer import build_solver_api
        return build_solver_api(cfg)
    if cfg.family == "encdec":
        table = _encdec.encdec_table(cfg)

        def forward(params, batch, sharder=None):
            enc_out = _encdec.encode(cfg, params, batch["enc_frames"],
                                     sharder=sharder)
            hidden = _encdec.decode_train(cfg, params, batch["tokens"], enc_out,
                                          sharder=sharder)
            return hidden, jnp.zeros((), jnp.float32)

        def prefill(params, batch, max_len, sharder=None):
            return _encdec.encdec_prefill(cfg, params, batch["tokens"],
                                          batch["enc_frames"], max_len,
                                          sharder=sharder)

        def decode_step(params, token, cache, kv_len, sharder=None):
            return _encdec.encdec_decode_step(cfg, params, token, cache, kv_len,
                                              sharder=sharder)

        def cache_shapes(batch, max_len, dtype=jnp.bfloat16):
            return _encdec.encdec_cache_shapes(cfg, batch, max_len, dtype)

        cache_dims = _encdec.encdec_cache_dims
    else:
        table = _tf.model_table(cfg)

        def forward(params, batch, sharder=None):
            return _tf.forward(
                cfg, params, batch["tokens"],
                positions=batch.get("positions"),
                vision_embeds=batch.get("vision_embeds"),
                sharder=sharder,
            )

        def prefill(params, batch, max_len, sharder=None):
            return _tf.prefill(
                cfg, params, batch["tokens"], max_len,
                positions=batch.get("positions"),
                vision_embeds=batch.get("vision_embeds"),
                sharder=sharder,
            )

        def decode_step(params, token, cache, kv_len, sharder=None):
            return _tf.decode_step(cfg, params, token, cache, kv_len,
                                   sharder=sharder)

        def cache_shapes(batch, max_len, dtype=jnp.bfloat16):
            return _tf.cache_shapes(cfg, batch, max_len, dtype)

        def cache_dims():
            return _tf.cache_dims(cfg)

    return ModelApi(
        cfg=cfg,
        table=table,
        init=lambda key, dtype=jnp.bfloat16: init_params(table, key, dtype),
        shapes=lambda dtype=jnp.bfloat16: param_shapes(table, dtype),
        dims=lambda: param_dims(table),
        forward=forward,
        prefill=prefill,
        decode_step=decode_step,
        cache_shapes=cache_shapes,
        cache_dims=cache_dims,
    )
