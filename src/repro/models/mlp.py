"""Dense FFN variants: gated (SwiGLU/GeGLU) and ungated (squared-ReLU, GELU)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.layers import ACTIVATIONS, ParamDef


def mlp_table(d_model: int, d_ff: int, gated: bool) -> dict:
    t = {
        "up": ParamDef((d_model, d_ff), ("embed", "dff")),
        "down": ParamDef((d_ff, d_model), ("dff", "embed")),
    }
    if gated:
        t["gate"] = ParamDef((d_model, d_ff), ("embed", "dff"))
    return t


def mlp_apply(params: dict, x: jnp.ndarray, activation: str, sharder=None) -> jnp.ndarray:
    act = ACTIVATIONS[activation]
    # bf16 outputs: fp32 dot outputs double HBM traffic and drag fp32 into
    # the backward collectives (§Perf B iteration 3)
    up = jnp.einsum("...d,df->...f", x, params["up"])
    if "gate" in params:
        gate = jnp.einsum("...d,df->...f", x, params["gate"])
        h = act(gate) * up
    else:
        h = act(up)
    h = h.astype(x.dtype)
    if sharder is not None:
        h = sharder.constrain(h, (*("batch", "seq")[: x.ndim - 1], "dff"))
    out = jnp.einsum("...f,fd->...d", h, params["down"])
    return out.astype(x.dtype)
