"""Mamba2 / SSD (state-space duality, arXiv:2405.21060) block.

The chunked SSD algorithm: within chunks of Q tokens the recurrence is
computed as masked-decay matmuls (MXU-shaped); across chunks a lax.scan
carries the (H, P, N) state.  ngroups=1 (all assigned SSM archs).

The depthwise causal conv over the x-path channels is the paper-technique
integration point (DESIGN §4): it calls ``core.conv1d.causal_conv1d`` — the
stencil engine's 1D causal encoding — and its decode step carries the K-1
left halo as recurrent state.  Projections are split (z / x / BC / dt) so
the inner dim and heads shard cleanly over the model axis; the depthwise
conv is channel-parallel, so TP costs it no communication.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.conv1d import causal_conv1d, causal_conv1d_update
from repro.models.layers import ParamDef, rms_norm


def mamba2_table(d_model: int, d_inner: int, n_heads: int, d_state: int,
                 d_conv: int) -> dict:
    return {
        "z_proj": ParamDef((d_model, d_inner), ("embed", "conv_channels")),
        "x_proj": ParamDef((d_model, d_inner), ("embed", "conv_channels")),
        "bc_proj": ParamDef((d_model, 2 * d_state), ("embed", None)),
        "dt_proj": ParamDef((d_model, n_heads), ("embed", "ssm_heads")),
        "conv_w": ParamDef((d_conv, d_inner), ("conv_kernel", "conv_channels"), scale=0.5),
        "conv_b": ParamDef((d_inner,), ("conv_channels",), scale="zero"),
        "bc_conv_w": ParamDef((d_conv, 2 * d_state), ("conv_kernel", None), scale=0.5),
        "bc_conv_b": ParamDef((2 * d_state,), (None,), scale="zero"),
        "A_log": ParamDef((n_heads,), ("ssm_heads",), scale="zero", dtype=jnp.float32),
        "D": ParamDef((n_heads,), ("ssm_heads",), scale="one", dtype=jnp.float32),
        "dt_bias": ParamDef((n_heads,), ("ssm_heads",), scale="zero", dtype=jnp.float32),
        "norm_w": ParamDef((d_inner,), ("conv_channels",), scale="one"),
        "out_proj": ParamDef((d_inner, d_model), ("conv_channels", "embed")),
    }


def _ssd_chunk(carry, inp, *, H, P, N):
    """One chunk of the SSD scan.  carry: state (B,H,P,N) fp32."""
    state = carry
    xdt, dA, Bc, Cc = inp          # (B,Q,H,P), (B,Q,H), (B,Q,N), (B,Q,N)
    Q = dA.shape[1]
    cum = jnp.cumsum(dA, axis=1)                    # (B,Q,H) fp32
    total = cum[:, -1]                              # (B,H)

    # Intra-chunk (diagonal block): scores[i,j] = (C_i.B_j) exp(cum_i - cum_j), i>=j
    # x/B/C stream in bf16, the decay matrix is exponentiated in fp32 then
    # cast for the matmuls, accumulation stays fp32 — the reference SSD
    # kernel's precision scheme (§Perf D iteration 2).
    cdtype = xdt.dtype
    CB = jnp.einsum("bin,bjn->bij", Cc, Bc,
                    preferred_element_type=jnp.float32)
    decay = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])       # (B,i,j,H)
    ii = jnp.arange(Q)
    causal = (ii[:, None] >= ii[None, :])[None, :, :, None]
    L = jnp.where(causal, decay, 0.0)
    y_diag = jnp.einsum("bij,bijh,bjhp->bihp", CB.astype(cdtype),
                        L.astype(cdtype), xdt,
                        preferred_element_type=jnp.float32)

    # Inter-chunk: contribution of the carried state to every position.
    y_off = jnp.einsum("bin,bhpn,bih->bihp", Cc.astype(jnp.float32), state,
                       jnp.exp(cum), preferred_element_type=jnp.float32)

    # State update: state' = state * exp(total) + sum_j B_j xdt_j exp(total - cum_j)
    decay_to_end = jnp.exp(total[:, None, :] - cum)                # (B,Q,H)
    new_state = state * jnp.exp(total)[:, :, None, None] + jnp.einsum(
        "bjn,bjh,bjhp->bhpn", Bc.astype(jnp.float32), decay_to_end,
        xdt.astype(jnp.float32), preferred_element_type=jnp.float32,
    )
    return new_state, y_diag + y_off


def ssd_scan(xdt, dA, B, C, chunk: int, state0=None):
    """Chunked SSD.  xdt: (B,L,H,P) fp32; dA: (B,L,H) fp32; B/C: (B,L,N) fp32.

    Returns (y (B,L,H,P) fp32, final_state (B,H,P,N) fp32).
    """
    Bb, L, H, P = xdt.shape
    N = B.shape[-1]
    if L % chunk:
        # ragged tail: zero-pad (xdt=0 contributes nothing; dA=0 decays by
        # exp(0)=1) — the final state is unaffected and y is sliced back.
        pad = chunk - L % chunk
        padt = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        y, final = ssd_scan(padt(xdt), padt(dA), padt(B), padt(C), chunk, state0)
        return y[:, :L], final
    nc = L // chunk

    def split(t):
        return jnp.moveaxis(t.reshape(Bb, nc, chunk, *t.shape[2:]), 1, 0)

    xs = (split(xdt), split(dA), split(B), split(C))
    state0 = (jnp.zeros((Bb, H, P, N), jnp.float32) if state0 is None
              else state0.astype(jnp.float32))
    body = jax.checkpoint(
        lambda c, i: _ssd_chunk(c, i, H=H, P=P, N=N)
    )
    final, ys = jax.lax.scan(body, state0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bb, L, H, P)
    return y, final


def mamba2_apply(params, x, *, n_heads, head_dim, d_state, chunk,
                 sharder=None, initial_state=None, return_state=False):
    """Full-sequence Mamba2 block.  x: (B, L, D) -> (B, L, D)."""
    Bb, L, D = x.shape
    d_inner = n_heads * head_dim

    z = jnp.einsum("bld,di->bli", x, params["z_proj"]).astype(x.dtype)
    xc = jnp.einsum("bld,di->bli", x, params["x_proj"]).astype(x.dtype)
    bc = jnp.einsum("bld,di->bli", x, params["bc_proj"]).astype(x.dtype)
    dt = jnp.einsum("bld,dh->blh", x, params["dt_proj"], preferred_element_type=jnp.float32)

    # Stencil-engine causal convs (paper-technique integration, DESIGN §4).
    xc = jax.nn.silu(causal_conv1d(xc, params["conv_w"], params["conv_b"]).astype(jnp.float32)).astype(x.dtype)
    bc = jax.nn.silu(causal_conv1d(bc, params["bc_conv_w"], params["bc_conv_b"]).astype(jnp.float32)).astype(x.dtype)
    if sharder is not None:
        xc = sharder.constrain(xc, ("batch", "seq", "conv_channels"))

    A = -jnp.exp(params["A_log"].astype(jnp.float32))              # (H,)
    dt = jax.nn.softplus(dt + params["dt_bias"].astype(jnp.float32))  # (B,L,H) fp32
    xh = xc.reshape(Bb, L, n_heads, head_dim)                       # bf16 stream
    Bmat, Cmat = jnp.split(bc, 2, axis=-1)                          # (B,L,N) bf16

    xdt = (xh.astype(jnp.float32) * dt[..., None]).astype(x.dtype)
    y, final = ssd_scan(xdt, dt * A, Bmat, Cmat, chunk,
                        state0=initial_state)
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] \
        * xh.astype(jnp.float32)
    y = y.reshape(Bb, L, d_inner).astype(x.dtype)

    # Gated RMSNorm then output projection.
    y = rms_norm((y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype),
                 params["norm_w"])
    out = jnp.einsum("bli,id->bld", y, params["out_proj"]).astype(x.dtype)
    if return_state:
        return out, final
    return out


def mamba2_decode(params, x_t, cache, *, n_heads, head_dim, d_state):
    """One-token decode.  x_t: (B, D); cache: dict(conv_x, conv_bc, state)."""
    Bb, D = x_t.shape
    d_inner = n_heads * head_dim

    z = (x_t @ params["z_proj"]).astype(x_t.dtype)
    xc = (x_t @ params["x_proj"]).astype(x_t.dtype)
    bc = (x_t @ params["bc_proj"]).astype(x_t.dtype)
    dt = (x_t @ params["dt_proj"]).astype(jnp.float32)

    conv_x, xc = causal_conv1d_update(cache["conv_x"], xc, params["conv_w"], params["conv_b"])
    conv_bc, bc = causal_conv1d_update(cache["conv_bc"], bc, params["bc_conv_w"], params["bc_conv_b"])
    xc = jax.nn.silu(xc.astype(jnp.float32))
    bc = jax.nn.silu(bc.astype(jnp.float32))

    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt + params["dt_bias"].astype(jnp.float32))   # (B,H)
    xh = xc.reshape(Bb, n_heads, head_dim)
    Bv, Cv = jnp.split(bc, 2, axis=-1)                                  # (B,N)

    state = cache["state"].astype(jnp.float32)                          # (B,H,P,N)
    decay = jnp.exp(dt * A)                                             # (B,H)
    state = state * decay[:, :, None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xh, Bv)
    y = jnp.einsum("bhpn,bn->bhp", state, Cv)
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(Bb, d_inner)

    y = rms_norm((y * jax.nn.silu(z.astype(jnp.float32))).astype(x_t.dtype),
                 params["norm_w"])
    out = (y @ params["out_proj"]).astype(x_t.dtype)
    new_cache = {"conv_x": conv_x, "conv_bc": conv_bc, "state": state.astype(cache["state"].dtype)}
    return out, new_cache


def mamba2_cache_shapes(batch: int, n_heads: int, head_dim: int, d_state: int,
                        d_conv: int, d_inner: int, dtype):
    return {
        "conv_x": jax.ShapeDtypeStruct((batch, d_conv - 1, d_inner), dtype),
        "conv_bc": jax.ShapeDtypeStruct((batch, d_conv - 1, 2 * d_state), dtype),
        "state": jax.ShapeDtypeStruct((batch, n_heads, head_dim, d_state), jnp.float32),
    }


def mamba2_cache_dims():
    return {
        "conv_x": ("batch", "conv_kernel", "conv_channels"),
        "conv_bc": ("batch", "conv_kernel", None),
        "state": ("batch", "ssm_heads", "ssm_headdim", "ssm_state"),
    }
