"""Shared model-layer primitives + declarative parameter tables.

Parameters are declared once as ``ParamDef(shape, dims, scale)`` tables; the
same table yields (a) initialized arrays, (b) ShapeDtypeStructs for the
dry-run (no allocation), and (c) the logical-dims tree the Sharder consumes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Mapping

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    dims: tuple[str | None, ...]
    scale: float | str = "fan_in"   # float -> normal(scale); "fan_in"; "zero";
                                    # "one"; "const:<v>" -> full(v)
    dtype: Any = None               # None -> model dtype

    def init(self, key, dtype):
        dt = self.dtype or dtype
        if self.scale == "zero":
            return jnp.zeros(self.shape, dt)
        if self.scale == "one":
            return jnp.ones(self.shape, dt)
        if isinstance(self.scale, str) and self.scale.startswith("const:"):
            # Deterministic constant init — solver-layer stencil weights
            # start at a known-stable operator, not at random noise.
            return jnp.full(self.shape, float(self.scale[6:]), dt)
        if self.scale == "fan_in":
            s = 1.0 / math.sqrt(max(1, self.shape[0]))
        else:
            s = float(self.scale)
        return (jax.random.normal(key, self.shape, jnp.float32) * s).astype(dt)


def init_params(table: Mapping[str, Any], key, dtype):
    """Materialize a (nested) ParamDef table into arrays."""
    flat = _flatten(table)
    keys = jax.random.split(key, len(flat))
    out = {}
    for (path, pd), k in zip(flat, keys):
        _set(out, path, pd.init(k, dtype))
    return out


def param_dims(table: Mapping[str, Any]):
    out = {}
    for path, pd in _flatten(table):
        _set(out, path, pd.dims)
    return out


def param_shapes(table: Mapping[str, Any], dtype):
    out = {}
    for path, pd in _flatten(table):
        out_dt = pd.dtype or dtype
        _set(out, path, jax.ShapeDtypeStruct(pd.shape, out_dt))
    return out


def stack_tables(table: Mapping[str, Any], n: int, dim_name: str = "layers"):
    """Prefix every ParamDef with a leading stacked-layers dim (for scan)."""
    out = {}
    for path, pd in _flatten(table):
        _set(out, path, ParamDef((n, *pd.shape), (None, *pd.dims), pd.scale, pd.dtype))
    return out


def _flatten(table, prefix=()):
    items = []
    for k, v in table.items():
        if isinstance(v, ParamDef):
            items.append(((*prefix, k), v))
        else:
            items.extend(_flatten(v, (*prefix, k)))
    return items


def _set(tree, path, value):
    for p in path[:-1]:
        tree = tree.setdefault(p, {})
    tree[path[-1]] = value


# ---------------------------------------------------------------------------
# Numerics
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32.

    Rotates pairs (x[..., :d/2], x[..., d/2:]) — the HF 'split-half'
    convention used by all assigned LM archs.
    """
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)          # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs        # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]                              # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1).astype(x.dtype)


def apply_mrope(
    x: jnp.ndarray,
    positions: jnp.ndarray,
    theta: float,
    sections: tuple[int, ...],
) -> jnp.ndarray:
    """Multimodal RoPE (Qwen2-VL, arXiv:2409.12191).

    positions: (3, batch, seq) — temporal / height / width position ids.
    The head_dim/2 frequency slots are partitioned into ``sections`` (summing
    to hd/2); each section takes its angle from the corresponding position
    channel.  For pure-text tokens all three channels are equal, reducing to
    standard RoPE.
    """
    hd = x.shape[-1]
    if sum(sections) != hd // 2:
        raise ValueError(f"mrope sections {sections} must sum to {hd // 2}")
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)          # (hd/2,)
    # angles per channel: (3, B, S, hd/2); section i reads channel i.
    angles_all = positions[..., None].astype(jnp.float32) * freqs
    parts, start = [], 0
    for i, s in enumerate(sections):
        parts.append(angles_all[i, ..., start : start + s])
        start += s
    angles = jnp.concatenate(parts, axis=-1)                         # (B, S, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1).astype(x.dtype)


ACTIVATIONS = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),  # squared ReLU (Primer / nemotron)
}
