"""Mixture-of-Experts FFN — GShard-style einsum dispatch with capacity,
group-scanned to bound live memory, experts sharded over the model axis (EP).

Dispatch: tokens are processed in groups of ``group_size``; a lax.scan over
groups keeps only one group's (S, E, C) one-hot tensors live at a time
(classic GShard materializes all groups at once — at 32k tokens × 128
experts that is GBs per device; the scan brings it to ~tens of MB at equal
FLOPs).  Within a group: top-k router, per-expert position by cumsum,
tokens beyond capacity dropped (cf=1.25), combine weighted by router prob.

The dispatch einsum contracts tokens(data-sharded) against experts
(model-sharded) — SPMD lowers it to the EP all-to-all.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import ACTIVATIONS, ParamDef


def moe_table(d_model: int, n_experts: int, d_ff: int, n_shared: int = 0) -> dict:
    t = {
        "router": ParamDef((d_model, n_experts), ("embed", "experts"), dtype=jnp.float32),
        "up": ParamDef((n_experts, d_model, d_ff), ("experts", "embed", "expert_dff")),
        "gate": ParamDef((n_experts, d_model, d_ff), ("experts", "embed", "expert_dff")),
        "down": ParamDef((n_experts, d_ff, d_model), ("experts", "expert_dff", "embed")),
    }
    if n_shared:
        t["shared"] = {
            "up": ParamDef((d_model, n_shared * d_ff), ("embed", "dff")),
            "gate": ParamDef((d_model, n_shared * d_ff), ("embed", "dff")),
            "down": ParamDef((n_shared * d_ff, d_model), ("dff", "embed")),
        }
    return t


def _group_moe(params, xg, top_k, capacity, activation, sharder,
               dispatch_mode="einsum"):
    """One wave of groups.  xg: (G, S, D) -> (G, S, D), plus aux-loss stats.

    G parallel groups (sharded over the batch axes — every device routes its
    own tokens concurrently); capacity/cumsum are per-group (local, no
    cross-device cumsum).  The dispatch einsum contracts the group-local
    token dim against model-sharded experts — the EP all-to-all.
    """
    G, S, D = xg.shape
    E = params["router"].shape[1]
    act = ACTIVATIONS[activation]

    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)                      # (G, S, E) fp32
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)          # (G, S, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Position of each (token, slot) within its expert, GShard priority order:
    # slot-major then token order; tokens past capacity are dropped.
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)    # (G, S, k, E)
    flat = onehot.transpose(0, 2, 1, 3).reshape(G, top_k * S, E)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat).reshape(
        G, top_k, S, E).transpose(0, 2, 1, 3)
    pos = jnp.sum(pos_in_expert * onehot, axis=-1)               # (G, S, k)
    keep = pos < capacity
    gate_vals = gate_vals * keep

    def expert_ffn(xin):
        """xin: (E, G, C, D) -> (E, G, C, D)."""
        if sharder is not None:
            xin = sharder.constrain(xin, ("experts", None, None, "embed"))
        up = jnp.einsum("egcd,edf->egcf", xin, params["up"])
        gate = jnp.einsum("egcd,edf->egcf", xin, params["gate"])
        h = (act(gate) * up).astype(xin.dtype)
        eout = jnp.einsum("egcf,efd->egcd", h, params["down"]).astype(xin.dtype)
        if sharder is not None:
            eout = sharder.constrain(eout, ("experts", None, None, "embed"))
        return eout

    if dispatch_mode == "scatter":
        # Beyond-paper (§Perf B): index-based dispatch — no (G,S,E,C) one-hot
        # tensors, no dispatch/combine matmul FLOPs.  Each (token, slot)
        # scatter-adds its activation into its expert slot row and gathers
        # the expert output back, weighted by the gate.
        slot = (expert_idx * capacity + pos.astype(jnp.int32))   # (G,S,k)
        slot = jnp.where(keep, slot, E * capacity)               # drop -> OOB
        buf = jnp.zeros((G, E * capacity + 1, D), xg.dtype)
        gsk = slot.reshape(G, S * top_k)
        xk = jnp.broadcast_to(xg[:, :, None, :], (G, S, top_k, D)
                              ).reshape(G, S * top_k, D)
        buf = jax.vmap(lambda b, i, v: b.at[i].add(v))(buf, gsk, xk)
        xin = buf[:, :-1].reshape(G, E, capacity, D).transpose(1, 0, 2, 3)
        eout = expert_ffn(xin)                                   # (E,G,C,D)
        flat = eout.transpose(1, 0, 2, 3).reshape(G, E * capacity, D)
        flat = jnp.concatenate([flat, jnp.zeros((G, 1, D), flat.dtype)], 1)
        picked = jax.vmap(lambda f, i: f[i])(flat, gsk)          # (G,S*k,D)
        picked = picked.reshape(G, S, top_k, D)
        out = jnp.einsum("gskd,gsk->gsd", picked.astype(jnp.float32),
                         gate_vals).astype(xg.dtype)
    else:
        # combine[g, s, e, c] = gate weight of token (g,s) in expert e, slot c
        pos_oh = jax.nn.one_hot(pos, capacity, dtype=jnp.float32)  # (G, S, k, C)
        combine = jnp.einsum("gsk,gske,gskc->gsec", gate_vals, onehot, pos_oh)
        dispatch = (combine > 0).astype(xg.dtype)                  # (G, S, E, C)
        if sharder is not None:
            dispatch = sharder.constrain(dispatch, ("moe_groups", None, None, None))
        # bf16 output on purpose: the EP collective (data->model resharding of
        # xin) must move bf16, not the fp32 pre-cast (§Perf B iteration 3)
        xin = jnp.einsum("gsec,gsd->egcd", dispatch, xg)
        eout = expert_ffn(xin)
        out = jnp.einsum("gsec,egcd->gsd", combine.astype(xg.dtype),
                         eout).astype(xg.dtype)

    # Switch aux-loss stats: fraction routed + mean router prob per expert.
    me = jnp.mean(probs, axis=(0, 1))                            # (E,)
    ce = jnp.mean(onehot[:, :, 0, :], axis=(0, 1))               # top-1 fraction
    return out, me, ce


def moe_apply(
    params: dict,
    x: jnp.ndarray,
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    group_size: int = 1024,
    activation: str = "silu",
    sharder=None,
    n_waves: int = 16,
    dispatch_mode: str = "einsum",
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out, aux_loss scalar).

    Tokens reshape to (waves, G, group_size, D): a lax.scan over waves bounds
    live dispatch memory; the G parallel groups per wave keep every data
    shard busy (G is batch-sharded).
    """
    B, S, D = x.shape
    E = params["router"].shape[1]
    tokens = B * S
    gs = min(group_size, tokens)
    n_groups = tokens // gs
    if tokens % gs:
        raise ValueError(f"tokens={tokens} not divisible by group_size={gs}")
    waves = min(n_waves, n_groups)
    while n_groups % waves:
        waves -= 1
    G = n_groups // waves
    capacity = max(4, int(gs * top_k * capacity_factor / E))

    xf = x.reshape(waves, G, gs, D)
    if sharder is not None:
        xf = sharder.constrain(xf, (None, "moe_groups", None, "embed"))

    def body(_, xg):
        out, me, ce = _group_moe(params, xg, top_k, capacity, activation,
                                 sharder, dispatch_mode)
        return None, (out, me, ce)

    # remat per wave: the backward recomputes one wave's dispatch/expert
    # activations at a time instead of saving all waves' (big, fp32) buffers
    _, (out, me, ce) = jax.lax.scan(jax.checkpoint(body), None, xf)
    aux = E * jnp.mean(jnp.sum(me[None] * ce[None], axis=-1))    # Switch aux loss

    out = out.reshape(B, S, D)
    if "shared" in params:
        sh = params["shared"]
        up = jnp.einsum("...d,df->...f", x, sh["up"])
        gate = jnp.einsum("...d,df->...f", x, sh["gate"])
        h = (ACTIVATIONS[activation](gate) * up).astype(x.dtype)
        out = out + jnp.einsum("...f,fd->...d", h, sh["down"]).astype(x.dtype)
    return out, aux
