"""Unified decoder-only transformer covering the dense / moe / ssm / hybrid /
vlm families, with scan-over-layers (O(1) HLO size), per-layer remat, and
logical-dims sharding annotations throughout.

Three execution modes share one block implementation:
  train   — full-seq causal forward, no cache;
  prefill — full-seq causal forward, returns per-layer caches (stacked);
  decode  — one token against the cache (attention: sequence-sharded cache,
            flash-decoding combine; ssm: O(1) state update).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import attention, decode_attention
from repro.models.layers import (
    ParamDef,
    apply_mrope,
    apply_rope,
    init_params,
    param_dims,
    param_shapes,
    rms_norm,
    stack_tables,
)
from repro.models.mlp import mlp_apply, mlp_table
from repro.models.moe import moe_apply, moe_table
from repro.models.ssm import (
    mamba2_apply,
    mamba2_cache_dims,
    mamba2_cache_shapes,
    mamba2_decode,
    mamba2_table,
)


# ---------------------------------------------------------------------------
# Parameter tables
# ---------------------------------------------------------------------------

def attn_table(cfg: ModelConfig) -> dict:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    t = {
        "wq": ParamDef((D, H, hd), ("embed", "heads", "head_dim")),
        "wk": ParamDef((D, KV, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamDef((D, KV, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamDef((H, hd, D), ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm:
        t["q_norm"] = ParamDef((hd,), ("head_dim",), scale="one")
        t["k_norm"] = ParamDef((hd,), ("head_dim",), scale="one")
    return t


def block_table(cfg: ModelConfig, kind: str) -> dict:
    D = cfg.d_model
    if kind == "mamba":
        return {
            "norm": ParamDef((D,), ("embed",), scale="one"),
            "mixer": mamba2_table(D, cfg.d_inner, cfg.n_ssm_heads,
                                  cfg.ssm_state, cfg.d_conv),
        }
    t = {
        "attn_norm": ParamDef((D,), ("embed",), scale="one"),
        "attn": attn_table(cfg),
        "mlp_norm": ParamDef((D,), ("embed",), scale="one"),
    }
    if kind == "moe":
        t["moe"] = moe_table(D, cfg.n_experts, cfg.d_ff_expert,
                             cfg.n_shared_experts)
    else:
        t["mlp"] = mlp_table(D, cfg.d_ff, cfg.gated_mlp)
    return t


def model_table(cfg: ModelConfig) -> dict:
    D, V = cfg.d_model, cfg.padded_vocab
    t: dict[str, Any] = {
        "embed": ParamDef((V, D), ("vocab", "embed"), scale=1.0),
        "final_norm": ParamDef((D,), ("embed",), scale="one"),
        "lm_head": ParamDef((V, D), ("vocab", "embed")),
    }
    if cfg.family in ("dense", "vlm"):
        t["layers"] = stack_tables(block_table(cfg, "dense"), cfg.n_layers)
    elif cfg.family == "moe":
        t["layers"] = stack_tables(block_table(cfg, "moe"), cfg.n_layers)
    elif cfg.family == "ssm":
        t["layers"] = stack_tables(block_table(cfg, "mamba"), cfg.n_layers)
    elif cfg.family == "hybrid":
        k = cfg.attn_every
        groups, rem = divmod(cfg.n_layers, k)
        t["layers"] = stack_tables(
            stack_tables(block_table(cfg, "mamba"), k), groups
        )
        if rem:
            t["tail_layers"] = stack_tables(block_table(cfg, "mamba"), rem)
        t["shared_attn"] = block_table(cfg, "dense")  # one block, reused
    else:
        raise ValueError(f"model_table does not handle family={cfg.family}")
    return t


def init_model(cfg: ModelConfig, key, dtype=jnp.bfloat16):
    return init_params(model_table(cfg), key, dtype)


def model_dims(cfg: ModelConfig):
    return param_dims(model_table(cfg))


def model_shapes(cfg: ModelConfig, dtype=jnp.bfloat16):
    return param_shapes(model_table(cfg), dtype)


# ---------------------------------------------------------------------------
# Attention sub-block
# ---------------------------------------------------------------------------

def _apply_qk_norm(p, q, k, eps):
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], eps)
        k = rms_norm(k, p["k_norm"], eps)
    return q, k


def _rope(cfg: ModelConfig, x, positions):
    if cfg.m_rope_sections is not None:
        return apply_mrope(x, positions, cfg.rope_theta, cfg.m_rope_sections)
    if positions is None:
        return x
    return apply_rope(x, positions, cfg.rope_theta)


def attn_apply(cfg: ModelConfig, p, x, *, positions, sharder, causal=True,
               kv_source=None, use_rope=True):
    """Full-sequence attention.  x: (B,S,D).  kv_source: cross-attn input."""
    src = x if kv_source is None else kv_source
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"]).astype(x.dtype)
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"]).astype(x.dtype)
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"]).astype(x.dtype)
    q, k = _apply_qk_norm(p, q, k, cfg.norm_eps)
    if use_rope and kv_source is None:
        q = _rope(cfg, q, positions)
        k = _rope(cfg, k, positions)
    shard_heads = False
    q_chunk = cfg.q_chunk
    if sharder is not None:
        if sharder.profile == "sp":
            # sequence parallelism: q stays seq-sharded, kv gathered full-seq
            q = sharder.constrain(q, ("batch", "seq", None, None))
            k = sharder.constrain(k, ("batch", None, None, None))
            v = sharder.constrain(v, ("batch", None, None, None))
            q_chunk = x.shape[1]
        else:
            q = sharder.constrain(q, ("batch", None, "heads", None))
            k = sharder.constrain(k, ("batch", None, None, None))
            v = sharder.constrain(v, ("batch", None, None, None))
            shard_heads = True
    if cfg.attn_impl == "flash" and sharder is None:
        # Pallas flash kernels (fwd + custom_vjp bwd); unsharded/TPU path —
        # the sharded dry-run keeps the XLA path so HLO cost stays visible
        from repro.kernels.flash_attention_bwd import flash_attention_trainable
        out = flash_attention_trainable(q, k, v, causal, 512, 512, 0)
    else:
        out = attention(q, k, v, causal=causal, q_chunk=q_chunk,
                        shard_heads=shard_heads)
    o = jnp.einsum("bshk,hkd->bsd", out, p["wo"]).astype(x.dtype)
    return o, (k, v)


def attn_decode_apply(cfg: ModelConfig, p, x, cache, kv_len, *, positions, sharder):
    """One-token attention.  x: (B,1,D); cache: {k,v}: (B,S_max,KV,hd)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"]).astype(x.dtype)
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"]).astype(x.dtype)
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"]).astype(x.dtype)
    q, k = _apply_qk_norm(p, q, k, cfg.norm_eps)
    q = _rope(cfg, q, positions)
    k = _rope(cfg, k, positions)
    k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, kv_len, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, kv_len, axis=1)
    if sharder is not None:
        k_cache = sharder.constrain(k_cache, ("batch", "kv_seq", None, None))
        v_cache = sharder.constrain(v_cache, ("batch", "kv_seq", None, None))
    out = decode_attention(q, k_cache, v_cache, kv_len + 1)
    o = jnp.einsum("bshk,hkd->bsd", out, p["wo"]).astype(x.dtype)
    return o, {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# Blocks (shared across modes)
# ---------------------------------------------------------------------------

def dense_block(cfg, p, x, *, positions, sharder, mode, cache=None, kv_len=0):
    h = rms_norm(x, p["attn_norm"], cfg.norm_eps)
    if mode == "decode":
        a, new_cache = attn_decode_apply(cfg, p["attn"], h, cache, kv_len,
                                         positions=positions, sharder=sharder)
    else:
        a, kv = attn_apply(cfg, p["attn"], h, positions=positions, sharder=sharder)
        new_cache = {"k": kv[0], "v": kv[1]} if mode == "prefill" else None
    x = x + a
    h = rms_norm(x, p["mlp_norm"], cfg.norm_eps)
    if "moe" in p:
        m, aux = moe_apply(
            p["moe"], h, top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
            group_size=cfg.moe_group_size if mode != "decode" else min(
                cfg.moe_group_size, h.shape[0] * h.shape[1]),
            activation=cfg.activation, sharder=sharder,
            n_waves=cfg.moe_waves, dispatch_mode=cfg.moe_dispatch,
        )
    else:
        m = mlp_apply(p["mlp"], h, cfg.activation, sharder)
        aux = jnp.zeros((), jnp.float32)
    return x + m, new_cache, aux


def mamba_block(cfg, p, x, *, sharder, mode, cache=None):
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    kw = dict(n_heads=cfg.n_ssm_heads, head_dim=cfg.ssm_head_dim,
              d_state=cfg.ssm_state)
    if mode == "decode":
        y, new_cache = mamba2_decode(p["mixer"], h[:, 0], cache, **kw)
        return x + y[:, None], new_cache
    if mode == "prefill":
        # run full-seq then capture final state + conv tails as the cache
        y, final = mamba2_apply(p["mixer"], h, chunk=cfg.ssm_chunk,
                                sharder=sharder, return_state=True, **kw)
        K = cfg.d_conv
        # conv halo: last K-1 *pre-conv* channel inputs
        xc = jnp.einsum("bld,di->bli", h[:, -(K - 1):], p["mixer"]["x_proj"],
                        preferred_element_type=jnp.float32).astype(x.dtype)
        bcc = jnp.einsum("bld,di->bli", h[:, -(K - 1):], p["mixer"]["bc_proj"],
                         preferred_element_type=jnp.float32).astype(x.dtype)
        new_cache = {"conv_x": xc, "conv_bc": bcc,
                     "state": final.astype(jnp.float32)}
        return x + y, new_cache
    y = mamba2_apply(p["mixer"], h, chunk=cfg.ssm_chunk, sharder=sharder, **kw)
    return x + y, None


# ---------------------------------------------------------------------------
# Model forward (mode-dispatched, scan-over-layers)
# ---------------------------------------------------------------------------

def _embed(cfg, params, tokens, sharder, vision_embeds=None):
    x = jnp.take(params["embed"], tokens, axis=0)
    if vision_embeds is not None:
        nv = vision_embeds.shape[1]
        x = jnp.concatenate([vision_embeds.astype(x.dtype), x[:, nv:]], axis=1)
    if sharder is not None:
        x = sharder.constrain(x, ("batch", "seq", "embed"))
    return x


def _run_layers(body, carry, stacked, remat: bool, remat_group: int):
    """Scan ``body`` over stacked layer params with group-granular remat.

    remat_group=g saves one residual set per g layers instead of per layer —
    g× less live activation memory in the backward for ~one extra forward
    recompute (and it sidesteps XLA hoisting the whole saved stack through
    a dtype convert — see EXPERIMENTS §Perf iteration 1).
    """
    n = jax.tree.leaves(stacked)[0].shape[0]
    if not remat:
        carry, _ = jax.lax.scan(body, carry, stacked)
        return carry
    g = remat_group if (remat_group > 1 and n % remat_group == 0) else 1
    if g == 1:
        carry, _ = jax.lax.scan(jax.checkpoint(body), carry, stacked)
        return carry
    grouped = jax.tree.map(lambda p: p.reshape(n // g, g, *p.shape[1:]), stacked)

    def outer(carry, gp):
        carry, _ = jax.lax.scan(body, carry, gp)
        return carry, None

    carry, _ = jax.lax.scan(jax.checkpoint(outer), carry, grouped)
    return carry


def forward(cfg: ModelConfig, params, tokens, *, positions=None, sharder=None,
            vision_embeds=None, remat=True):
    """Train-mode forward.  Returns (final hidden (B,S,D), aux_loss)."""
    x = _embed(cfg, params, tokens, sharder, vision_embeds)
    if positions is None:
        positions = _default_positions(cfg, tokens)
    aux0 = jnp.zeros((), jnp.float32)

    if cfg.family in ("dense", "vlm", "moe"):
        def body(carry, lp):
            x, aux = carry
            x, _, a = dense_block(cfg, lp, x, positions=positions,
                                  sharder=sharder, mode="train")
            if sharder is not None:
                x = sharder.constrain(x, ("batch", "seq", "embed"))
            return (x, aux + a), None
        (x, aux0) = _run_layers(body, (x, aux0), params["layers"], remat,
                                cfg.remat_group)
    elif cfg.family == "ssm":
        def body(x, lp):
            x, _ = mamba_block(cfg, lp, x, sharder=sharder, mode="train")
            if sharder is not None:
                x = sharder.constrain(x, ("batch", "seq", "embed"))
            return x, None
        x = _run_layers(body, x, params["layers"], remat, cfg.remat_group)
    elif cfg.family == "hybrid":
        def inner(x, lp):
            x, _ = mamba_block(cfg, lp, x, sharder=sharder, mode="train")
            return x, None
        def group(x, gp):
            x, _ = jax.lax.scan(inner, x, gp)
            x, _, _ = dense_block(cfg, params["shared_attn"], x,
                                  positions=positions, sharder=sharder,
                                  mode="train")
            if sharder is not None:
                x = sharder.constrain(x, ("batch", "seq", "embed"))
            return x, None
        x, _ = jax.lax.scan(jax.checkpoint(group) if remat else group,
                            x, params["layers"])
        if "tail_layers" in params:
            x = _run_layers(inner, x, params["tail_layers"], remat, 1)
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux0


def _default_positions(cfg, tokens):
    B, S = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if cfg.m_rope_sections is not None:
        return jnp.broadcast_to(pos, (3, B, S))
    return pos


def mask_pad_logits(logits, cfg: ModelConfig):
    """-inf on the padded vocab rows (see ModelConfig.padded_vocab)."""
    if cfg.padded_vocab == cfg.vocab_size:
        return logits
    ids = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    return jnp.where(ids < cfg.vocab_size, logits, -1e30)


def logits_from_hidden(params, hidden):
    """(B,S,D) @ lm_head.T — callers chunk this (train/xent handles vocab)."""
    return jnp.einsum("bsd,vd->bsv", hidden, params["lm_head"],
                      preferred_element_type=jnp.float32)


# -- caches ------------------------------------------------------------------

def attn_cache_shapes(cfg: ModelConfig, batch: int, max_len: int, dtype):
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jax.ShapeDtypeStruct((batch, max_len, KV, hd), dtype),
        "v": jax.ShapeDtypeStruct((batch, max_len, KV, hd), dtype),
    }


def attn_cache_dims():
    return {"k": ("batch", "kv_seq", "kv_heads", "head_dim"),
            "v": ("batch", "kv_seq", "kv_heads", "head_dim")}


def _stack_shapes(shapes, n):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n, *s.shape), s.dtype), shapes)


def _stack_dims(dims, extra=1):
    return jax.tree.map(
        lambda d: tuple([None] * extra + list(d)), dims,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


def cache_shapes(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """ShapeDtypeStructs for the full decode cache of this model."""
    if cfg.family in ("dense", "vlm", "moe"):
        return _stack_shapes(attn_cache_shapes(cfg, batch, max_len, dtype),
                             cfg.n_layers)
    if cfg.family == "ssm":
        return _stack_shapes(
            mamba2_cache_shapes(batch, cfg.n_ssm_heads, cfg.ssm_head_dim,
                                cfg.ssm_state, cfg.d_conv, cfg.d_inner, dtype),
            cfg.n_layers)
    if cfg.family == "hybrid":
        k = cfg.attn_every
        groups, rem = divmod(cfg.n_layers, k)
        m = mamba2_cache_shapes(batch, cfg.n_ssm_heads, cfg.ssm_head_dim,
                                cfg.ssm_state, cfg.d_conv, cfg.d_inner, dtype)
        out = {"groups": _stack_shapes(_stack_shapes(m, k), groups),
               "attn": _stack_shapes(attn_cache_shapes(cfg, batch, max_len, dtype), groups)}
        if rem:
            out["tail"] = _stack_shapes(m, rem)
        return out
    raise ValueError(cfg.family)


def cache_dims(cfg: ModelConfig):
    if cfg.family in ("dense", "vlm", "moe"):
        return _stack_dims(attn_cache_dims())
    if cfg.family == "ssm":
        return _stack_dims(mamba2_cache_dims())
    if cfg.family == "hybrid":
        rem = cfg.n_layers % cfg.attn_every
        out = {"groups": _stack_dims(mamba2_cache_dims(), extra=2),
               "attn": _stack_dims(attn_cache_dims())}
        if rem:
            out["tail"] = _stack_dims(mamba2_cache_dims())
        return out
    raise ValueError(cfg.family)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_shapes(cfg, batch, max_len, dtype))


# -- prefill / decode ---------------------------------------------------------

def prefill(cfg: ModelConfig, params, tokens, max_len, *, positions=None,
            sharder=None, vision_embeds=None, dtype=jnp.bfloat16):
    """Process a prompt; returns (last-position hidden (B,D), cache)."""
    B, S = tokens.shape
    x = _embed(cfg, params, tokens, sharder, vision_embeds)
    if positions is None:
        positions = _default_positions(cfg, tokens)

    def pad_kv(kv):
        k, v = kv["k"], kv["v"]
        pad = [(0, 0), (0, max_len - k.shape[1]), (0, 0), (0, 0)]
        out = {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}
        if sharder is not None:
            out = {n: sharder.constrain(t, ("batch", "kv_seq", None, None))
                   for n, t in out.items()}
        return out

    if cfg.family in ("dense", "vlm", "moe"):
        def body(x, lp):
            x, cache, _ = dense_block(cfg, lp, x, positions=positions,
                                      sharder=sharder, mode="prefill")
            return x, pad_kv(cache)
        x, caches = jax.lax.scan(jax.checkpoint(body), x, params["layers"])
    elif cfg.family == "ssm":
        def body(x, lp):
            x, cache = mamba_block(cfg, lp, x, sharder=sharder, mode="prefill")
            return x, cache
        x, caches = jax.lax.scan(jax.checkpoint(body), x, params["layers"])
    elif cfg.family == "hybrid":
        def inner(x, lp):
            x, c = mamba_block(cfg, lp, x, sharder=sharder, mode="prefill")
            return x, c
        def group(x, gp):
            x, mc = jax.lax.scan(jax.checkpoint(inner), x, gp)
            x, ac, _ = dense_block(cfg, params["shared_attn"], x,
                                   positions=positions, sharder=sharder,
                                   mode="prefill")
            return x, (mc, pad_kv(ac))
        x, (mcs, acs) = jax.lax.scan(jax.checkpoint(group), x, params["layers"])
        caches = {"groups": mcs, "attn": acs}
        if "tail_layers" in params:
            x, tc = jax.lax.scan(jax.checkpoint(inner), x, params["tail_layers"])
            caches["tail"] = tc
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x[:, -1], caches


def decode_step(cfg: ModelConfig, params, token, cache, kv_len, *,
                sharder=None):
    """One decode step.  token: (B,) int32; kv_len: int (current cache fill).

    Returns (logits (B, V) fp32, updated cache).
    """
    B = token.shape[0]
    x = jnp.take(params["embed"], token[:, None], axis=0)
    if sharder is not None:
        x = sharder.constrain(x, ("batch", None, "embed"))
    pos = jnp.full((B, 1), kv_len, jnp.int32)
    if cfg.m_rope_sections is not None:
        pos = jnp.broadcast_to(pos, (3, B, 1))

    if cfg.family in ("dense", "vlm", "moe"):
        def body(x, inp):
            lp, c = inp
            x, nc, _ = dense_block(cfg, lp, x, positions=pos, sharder=sharder,
                                   mode="decode", cache=c, kv_len=kv_len)
            return x, nc
        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    elif cfg.family == "ssm":
        def body(x, inp):
            lp, c = inp
            x, nc = mamba_block(cfg, lp, x, sharder=sharder, mode="decode",
                                cache=c)
            return x, nc
        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    elif cfg.family == "hybrid":
        def inner(x, inp):
            lp, c = inp
            x, nc = mamba_block(cfg, lp, x, sharder=sharder, mode="decode",
                                cache=c)
            return x, nc
        def group(x, inp):
            gp, mc, ac = inp
            x, nmc = jax.lax.scan(inner, x, (gp, mc))
            x, nac, _ = dense_block(cfg, params["shared_attn"], x,
                                    positions=pos, sharder=sharder,
                                    mode="decode", cache=ac, kv_len=kv_len)
            return x, (nmc, nac)
        x, (nmc, nac) = jax.lax.scan(
            group, x, (params["layers"], cache["groups"], cache["attn"]))
        new_cache = {"groups": nmc, "attn": nac}
        if "tail_layers" in params:
            x, ntc = jax.lax.scan(inner, x, (params["tail_layers"], cache["tail"]))
            new_cache["tail"] = ntc
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["lm_head"],
                        preferred_element_type=jnp.float32)
    logits = mask_pad_logits(logits, cfg)
    return logits[:, 0], new_cache
