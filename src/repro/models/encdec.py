"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

Per the assignment the conv frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings (B, enc_len, D); the stencil-engine conv stem
exists in core/ but is not on this path (DESIGN §4).  Encoder: bidirectional
attention blocks.  Decoder: causal self-attention + cross-attention + GELU
MLP.  LayerNorm, learned decoder positions, sinusoid encoder positions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.attention import decode_attention
from repro.models.layers import ParamDef, layer_norm, stack_tables
from repro.models.mlp import mlp_apply, mlp_table
from repro.models.transformer import (
    attn_apply,
    attn_cache_shapes,
    attn_table,
    _stack_shapes,
)


def _ln(d):
    return {"w": ParamDef((d,), ("embed",), scale="one"),
            "b": ParamDef((d,), ("embed",), scale="zero")}


def enc_block_table(cfg: ModelConfig) -> dict:
    return {
        "ln1": _ln(cfg.d_model),
        "attn": attn_table(cfg),
        "ln2": _ln(cfg.d_model),
        "mlp": mlp_table(cfg.d_model, cfg.d_ff, gated=False),
    }


def dec_block_table(cfg: ModelConfig) -> dict:
    return {
        "ln1": _ln(cfg.d_model),
        "self_attn": attn_table(cfg),
        "ln2": _ln(cfg.d_model),
        "cross_attn": attn_table(cfg),
        "ln3": _ln(cfg.d_model),
        "mlp": mlp_table(cfg.d_model, cfg.d_ff, gated=False),
    }


def encdec_table(cfg: ModelConfig, max_dec_positions: int = 32768) -> dict:
    D, V = cfg.d_model, cfg.padded_vocab
    return {
        "embed": ParamDef((V, D), ("vocab", "embed"), scale=1.0),
        "dec_pos": ParamDef((max_dec_positions, D), (None, "embed"), scale=0.02),
        "enc_layers": stack_tables(enc_block_table(cfg), cfg.n_enc_layers),
        "dec_layers": stack_tables(dec_block_table(cfg), cfg.n_layers),
        "enc_ln": _ln(D),
        "dec_ln": _ln(D),
        "lm_head": ParamDef((V, D), ("vocab", "embed")),
    }


def _sinusoid(length: int, d: int) -> np.ndarray:
    pos = np.arange(length)[:, None]
    dim = np.arange(d // 2)[None, :]
    inv = np.exp(-np.log(10000.0) * dim / max(1, d // 2 - 1))
    ang = pos * inv
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=1).astype(np.float32)


def encode(cfg: ModelConfig, params, frames, *, sharder=None, remat=True):
    """frames: (B, enc_len, D) stub embeddings -> (B, enc_len, D)."""
    B, T, D = frames.shape
    x = frames + jnp.asarray(_sinusoid(T, D), frames.dtype)[None]
    if sharder is not None:
        x = sharder.constrain(x, ("batch", "enc_seq", "embed"))

    def body(x, lp):
        h = layer_norm(x, lp["ln1"]["w"], lp["ln1"]["b"], cfg.norm_eps)
        a, _ = attn_apply(cfg, lp["attn"], h, positions=None, sharder=None,
                          causal=False, use_rope=False)
        x = x + a
        h = layer_norm(x, lp["ln2"]["w"], lp["ln2"]["b"], cfg.norm_eps)
        x = x + mlp_apply(lp["mlp"], h, "gelu")
        return x, None

    f = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(f, x, params["enc_layers"])
    return layer_norm(x, params["enc_ln"]["w"], params["enc_ln"]["b"], cfg.norm_eps)


def _dec_block(cfg, lp, x, enc_out, positions, sharder, mode,
               cache=None, kv_len=0):
    h = layer_norm(x, lp["ln1"]["w"], lp["ln1"]["b"], cfg.norm_eps)
    if mode == "decode":
        from repro.models.transformer import attn_decode_apply
        a, self_cache = attn_decode_apply(cfg, lp["self_attn"], h, cache["self"],
                                          kv_len, positions=None, sharder=sharder)
    else:
        a, kv = attn_apply(cfg, lp["self_attn"], h, positions=None,
                           sharder=sharder, causal=True, use_rope=False)
        self_cache = {"k": kv[0], "v": kv[1]} if mode == "prefill" else None
    x = x + a

    h = layer_norm(x, lp["ln2"]["w"], lp["ln2"]["b"], cfg.norm_eps)
    if mode == "decode":
        q = jnp.einsum("bsd,dhk->bshk", h, lp["cross_attn"]["wq"],
                       preferred_element_type=jnp.float32).astype(h.dtype)
        out = decode_attention(q, cache["cross_k"], cache["cross_v"],
                               cache["cross_k"].shape[1])
        a = jnp.einsum("bshk,hkd->bsd", out, lp["cross_attn"]["wo"],
                       preferred_element_type=jnp.float32).astype(h.dtype)
        cross_k, cross_v = cache["cross_k"], cache["cross_v"]
    else:
        a, crosskv = attn_apply(cfg, lp["cross_attn"], h, positions=None,
                                sharder=sharder, causal=False,
                                kv_source=enc_out, use_rope=False)
        cross_k, cross_v = crosskv
    x = x + a

    h = layer_norm(x, lp["ln3"]["w"], lp["ln3"]["b"], cfg.norm_eps)
    x = x + mlp_apply(lp["mlp"], h, "gelu")
    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"self": self_cache, "cross_k": cross_k, "cross_v": cross_v}
    return x, new_cache


def decode_train(cfg: ModelConfig, params, tokens, enc_out, *, sharder=None,
                 remat=True):
    """Teacher-forced decoder pass -> final hidden (B, S, D)."""
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x + params["dec_pos"][:S][None].astype(x.dtype)
    if sharder is not None:
        x = sharder.constrain(x, ("batch", "seq", "embed"))

    def body(x, lp):
        x, _ = _dec_block(cfg, lp, x, enc_out, None, sharder, "train")
        return x, None

    f = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(f, x, params["dec_layers"])
    return layer_norm(x, params["dec_ln"]["w"], params["dec_ln"]["b"], cfg.norm_eps)


def encdec_cache_shapes(cfg: ModelConfig, batch: int, max_len: int, dtype):
    per = {
        "self": attn_cache_shapes(cfg, batch, max_len, dtype),
        "cross_k": jax.ShapeDtypeStruct(
            (batch, cfg.enc_len, cfg.n_kv_heads, cfg.head_dim), dtype),
        "cross_v": jax.ShapeDtypeStruct(
            (batch, cfg.enc_len, cfg.n_kv_heads, cfg.head_dim), dtype),
    }
    return _stack_shapes(per, cfg.n_layers)


def encdec_cache_dims():
    return {
        "self": {"k": (None, "batch", "kv_seq", "kv_heads", "head_dim"),
                 "v": (None, "batch", "kv_seq", "kv_heads", "head_dim")},
        "cross_k": (None, "batch", "enc_seq", "kv_heads", "head_dim"),
        "cross_v": (None, "batch", "enc_seq", "kv_heads", "head_dim"),
    }


def encdec_prefill(cfg: ModelConfig, params, tokens, enc_frames, max_len, *,
                   sharder=None):
    """Encode + teacher-forced decoder prefill -> (last hidden, cache)."""
    enc_out = encode(cfg, params, enc_frames, sharder=sharder)
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x + params["dec_pos"][:S][None].astype(x.dtype)
    if sharder is not None:
        x = sharder.constrain(x, ("batch", "seq", "embed"))

    def pad_self(kv):
        pad = [(0, 0), (0, max_len - kv["k"].shape[1]), (0, 0), (0, 0)]
        out = {"k": jnp.pad(kv["k"], pad), "v": jnp.pad(kv["v"], pad)}
        if sharder is not None:
            out = {n: sharder.constrain(t, ("batch", "kv_seq", None, None))
                   for n, t in out.items()}
        return out

    def body(x, lp):
        x, c = _dec_block(cfg, lp, x, enc_out, None, sharder, "prefill")
        c["self"] = pad_self(c["self"])
        return x, c

    x, caches = jax.lax.scan(jax.checkpoint(body), x, params["dec_layers"])
    x = layer_norm(x, params["dec_ln"]["w"], params["dec_ln"]["b"], cfg.norm_eps)
    return x[:, -1], caches


def encdec_decode_step(cfg: ModelConfig, params, token, cache, kv_len, *,
                       sharder=None):
    B = token.shape[0]
    x = jnp.take(params["embed"], token[:, None], axis=0)
    x = x + jax.lax.dynamic_slice_in_dim(params["dec_pos"], kv_len, 1, 0)[None].astype(x.dtype)
    if sharder is not None:
        x = sharder.constrain(x, ("batch", None, "embed"))

    def body(x, inp):
        lp, c = inp
        x, nc = _dec_block(cfg, lp, x, None, None, sharder, "decode",
                           cache=c, kv_len=kv_len)
        return x, nc

    x, new_cache = jax.lax.scan(body, x, (params["dec_layers"], cache))
    x = layer_norm(x, params["dec_ln"]["w"], params["dec_ln"]["b"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["lm_head"],
                        preferred_element_type=jnp.float32)
    from repro.models.transformer import mask_pad_logits
    logits = mask_pad_logits(logits, cfg)
    return logits[:, 0], new_cache
