"""Serving launcher: batched prefill + greedy decode with the sharded cache.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \\
      --batch 4 --prompt-len 32 --tokens 16

On this CPU container use --smoke; the full configs are exercised by the
decode_*/prefill_* dry-run cells.
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.models.model_zoo import build
    from repro.parallel.sharding import Sharder
    from repro.train.serve_step import make_decode_step, make_prefill_step

    cfg = get_config(args.arch, smoke=args.smoke)
    api = build(cfg)
    mesh = make_host_mesh()
    sharder = Sharder(mesh=mesh, profile=cfg.sharding_profile)
    params = api.init(jax.random.PRNGKey(0), jnp.bfloat16)
    rng = np.random.default_rng(0)
    B, S = args.batch, args.prompt_len
    max_len = S + args.tokens + 1
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))}
    if cfg.family == "encdec":
        batch["enc_frames"] = jnp.zeros((B, cfg.enc_len, cfg.d_model),
                                        jnp.bfloat16)
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.zeros(
            (B, min(cfg.n_vision_tokens, S), cfg.d_model), jnp.bfloat16)

    with mesh:
        prefill = jax.jit(make_prefill_step(api, sharder, max_len))
        t0 = time.perf_counter()
        token, cache = jax.block_until_ready(prefill(params, batch))
        t_pre = time.perf_counter() - t0
        print(f"prefill {B}x{S}: {t_pre*1e3:.0f} ms ({B*S/t_pre:.0f} tok/s)")

        out = [token]
        t0 = time.perf_counter()
        for i in range(args.tokens):
            step = jax.jit(make_decode_step(api, sharder, S + i))
            token, cache = step(params, token, cache)
            out.append(token)
        jax.block_until_ready(token)
        dt = (time.perf_counter() - t0) / args.tokens
    print(f"decode: {dt*1e3:.1f} ms/token (incl per-position compile)")
    print("seq0:", [int(t[0]) for t in out])
    return 0


if __name__ == "__main__":
    sys.exit(main())
