"""Training launcher: ``python -m repro.launch.train --arch qwen3-0.6b --smoke ...``

Builds the mesh, sharded train step, synthetic data pipeline, and drives the
fault-tolerant runtime.  On this CPU container use --smoke (reduced config);
the full configs are exercised via the dry-run.
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint-dir", default="artifacts/ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=10)
    ap.add_argument("--fail-at-step", type=int, default=None)
    ap.add_argument("--log-every", type=int, default=1)
    args = ap.parse_args(argv)

    import jax
    from repro.configs import get_config
    from repro.data.synthetic import DataConfig, token_batch
    from repro.launch.mesh import make_host_mesh
    from repro.models.model_zoo import build
    from repro.optim.adamw import AdamWConfig
    from repro.parallel.sharding import Sharder, tree_shardings
    from repro.runtime.ft import FTConfig, run_training
    from repro.train.train_step import (
        init_train_state, make_train_step, state_dims,
    )

    cfg = get_config(args.arch, smoke=args.smoke)
    api = build(cfg)
    mesh = make_host_mesh()
    sharder = Sharder(mesh=mesh, profile=cfg.sharding_profile)
    opt = AdamWConfig(lr=args.lr, total_steps=args.steps,
                      warmup_steps=max(1, args.steps // 10))
    step_fn = make_train_step(api, sharder, opt)

    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                          global_batch=args.global_batch)

    def batch_for_step(step):
        b = token_batch(data_cfg, step)
        extra = {}
        if cfg.family == "encdec":
            import jax.numpy as jnp
            extra["enc_frames"] = jnp.zeros(
                (args.global_batch, cfg.enc_len, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            import jax.numpy as jnp
            extra["vision_embeds"] = jnp.zeros(
                (args.global_batch, min(cfg.n_vision_tokens, args.seq_len),
                 cfg.d_model), jnp.bfloat16)
        return {**b, **extra}

    def init_state():
        return init_train_state(api, jax.random.PRNGKey(0))

    sdims = state_dims(api)
    import jax.numpy as jnp
    from repro.train.train_step import state_shapes
    sshapes = jax.tree.map(lambda s: s.shape, state_shapes(api),
                           is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    shardings = tree_shardings(sharder, sdims, sshapes)

    ft = FTConfig(checkpoint_dir=args.checkpoint_dir,
                  checkpoint_every=args.checkpoint_every,
                  fail_at_step=args.fail_at_step)

    def on_step(st):
        if st.step % args.log_every == 0:
            flag = " STRAGGLER" if st.is_straggler else ""
            print(f"step {st.step:5d} loss={st.metrics['loss']:.4f} "
                  f"nll={st.metrics['nll']:.4f} lr={st.metrics['lr']:.2e} "
                  f"gnorm={st.metrics['grad_norm']:.3f} {st.seconds*1e3:.0f}ms"
                  f"{flag}", flush=True)

    with mesh:
        jitted = jax.jit(step_fn, in_shardings=(shardings, None),
                         donate_argnums=(0,))
        state, stats = run_training(
            jitted, init_state, batch_for_step, args.steps, ft,
            state_shardings=shardings, on_step=on_step)
    losses = [s.metrics["loss"] for s in stats]
    if losses:
        print(f"done: first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
