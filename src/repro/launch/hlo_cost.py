"""Trip-count-aware cost analysis over optimized HLO text.

``compiled.cost_analysis()`` counts every while-loop body ONCE — for
scan-over-layers models that undercounts FLOPs by ~n_layers× (verified: a
16-step scanned matmul reports the flops of one step).  This module walks
the HLO call graph, multiplies each computation's costs by the product of
enclosing loop trip counts (from the while instruction's
``known_trip_count`` backend_config, falling back to the s32 constant in the
loop condition), and reports:

  flops            dot/convolution FLOPs (the MXU term)
  hbm_bytes        estimated HBM traffic: Σ (result + operand bytes) over
                   materializing top-level instructions — fusion internals
                   excluded (they live in registers/VMEM)
  collectives      per-kind {count, operand_bytes, result_bytes}, trip-aware

All quantities are per-device (the HLO is the SPMD-partitioned program).
"""
from __future__ import annotations

import dataclasses
import json
import re

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_ALIAS_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "after-all", "iota",
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


@dataclasses.dataclass
class Instr:
    name: str
    shape_str: str
    opcode: str
    operands: list[str]
    attrs: str
    dims: tuple[int, ...] | None
    dtype: str | None
    raw_operands: str = ""

    @property
    def result_bytes(self) -> int:
        return _shape_bytes(self.shape_str)

    @property
    def n_elements(self) -> int:
        if self.dims is None:
            return 0
        n = 1
        for d in self.dims:
            n *= d
        return n


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"((?:\([^()]*\))|(?:[\w:]+\[[\d,]*\](?:\{[^}]*\})?))\s+"
    r"([\w\-]+)\((.*)$"
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.*\{\s*$")
_SINGLE_SHAPE_RE = re.compile(r"^(\w+)\[([\d,]*)\]")


def parse_module(text: str) -> tuple[dict[str, list[Instr]], str]:
    """Returns ({computation -> [Instr]}, entry_name)."""
    comps: dict[str, list[Instr]] = {}
    entry = None
    cur: list[Instr] | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        mc = _COMP_RE.match(line.strip())
        if mc and not line.strip().startswith("%param"):
            name = mc.group(1)
            cur = comps.setdefault(name, [])
            if raw.lstrip().startswith("ENTRY"):
                entry = name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        name, shape_str, opcode, rest = mi.groups()
        # split rest at the closing paren of the operand list (balance parens)
        depth = 1
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        operand_str, attrs = rest[:i], rest[i + 1:]
        operands = re.findall(r"%([\w.\-]+)", operand_str)
        ms = _SINGLE_SHAPE_RE.match(shape_str)
        dims = None
        dtype = None
        if ms:
            dtype = ms.group(1)
            dims = tuple(int(d) for d in ms.group(2).split(",")) if ms.group(2) else ()
        cur.append(Instr(name, shape_str, opcode, operands, attrs, dims, dtype,
                         raw_operands=operand_str))
    if entry is None:
        raise ValueError("no ENTRY computation found")
    return comps, entry


def _trip_count(instr: Instr, comps: dict[str, list[Instr]],
                const_of: dict[str, int]) -> int:
    m = re.search(r"known_trip_count\D*(\d+)", instr.attrs)
    if m:
        return int(m.group(1))
    mc = re.search(r"condition=%?([\w.\-]+)", instr.attrs)
    if mc and mc.group(1) in comps:
        consts = [const_of[i2.name] for i2 in comps[mc.group(1)]
                  if i2.name in const_of]
        if consts:
            return max(consts)
    return 1


def _dot_flops(instr: Instr, shape_of: dict[str, tuple]) -> float:
    out_elems = instr.n_elements
    lhs = shape_of.get(instr.operands[0]) if instr.operands else None
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.attrs)
    contract = 1
    if lhs and m and m.group(1):
        for d in m.group(1).split(","):
            contract *= lhs[int(d)]
    return 2.0 * out_elems * contract


def _conv_flops(instr: Instr, shape_of: dict[str, tuple]) -> float:
    out_elems = instr.n_elements
    ker = shape_of.get(instr.operands[1]) if len(instr.operands) > 1 else None
    if not ker:
        return 0.0
    m = re.search(r"dim_labels=\w*_(\w+)->", instr.attrs)
    ker_elems = 1
    for d in ker:
        ker_elems *= d
    out_feats = 1
    if m:
        labels = m.group(1)  # e.g. "01io" or "io01"
        if "o" in labels:
            out_feats = ker[labels.index("o")]
    return 2.0 * out_elems * (ker_elems / max(1, out_feats))


def analyze(text: str) -> dict:
    comps, entry = parse_module(text)
    shape_of: dict[str, tuple] = {}
    bytes_of: dict[str, int] = {}
    const_of: dict[str, int] = {}
    for instrs in comps.values():
        for i in instrs:
            if i.dims is not None:
                shape_of[i.name] = i.dims
            bytes_of[i.name] = i.result_bytes
            if i.opcode == "constant" and i.dtype in ("s32", "u32", "s64"):
                mm = re.match(r"\s*(\d+)", i.raw_operands)
                if mm:
                    const_of[i.name] = int(mm.group(1))

    # computation multipliers via DFS over the call graph
    mult: dict[str, float] = {}

    def visit(comp: str, m: float):
        mult[comp] = mult.get(comp, 0.0) + m
        for i in comps.get(comp, []):
            sub = m
            if i.opcode == "while":
                body = re.search(r"body=%?([\w.\-]+)", i.attrs)
                cond = re.search(r"condition=%?([\w.\-]+)", i.attrs)
                tc = _trip_count(i, comps, const_of)
                if body:
                    visit(body.group(1), sub * tc)
                if cond:
                    visit(cond.group(1), sub * (tc + 1))
            elif i.opcode == "conditional":
                for b in re.findall(r"(?:branch_computations=\{([^}]*)\}|"
                                    r"(?:true|false)_computation=%?([\w.\-]+))",
                                    i.attrs):
                    for name in re.findall(r"%?([\w.\-]+)", ",".join(x for x in b if x)):
                        if name in comps:
                            visit(name, sub)
            elif i.opcode in ("fusion", "call", "custom-call", "reduce",
                              "reduce-window", "scatter", "sort", "map",
                              "all-reduce", "reduce-scatter"):
                mcall = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", i.attrs)
                if mcall and mcall.group(1) in comps:
                    # fusion internals: counted for FLOPs, not for HBM bytes
                    visit(mcall.group(1), sub)

    visit(entry, 1.0)

    # which computations are fusion-internal (not memory-level)?
    fusion_called: set[str] = set()
    for instrs in comps.values():
        for i in instrs:
            if i.opcode in ("fusion", "map", "reduce", "reduce-window",
                            "scatter", "sort", "all-reduce", "reduce-scatter"):
                mcall = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", i.attrs)
                if mcall:
                    fusion_called.add(mcall.group(1))

    flops = 0.0
    hbm_bytes = 0.0
    coll = {k: {"count": 0.0, "operand_bytes": 0.0, "result_bytes": 0.0}
            for k in _COLLECTIVES}

    for comp, instrs in comps.items():
        m = mult.get(comp, 0.0)
        if m == 0.0:
            continue
        mem_level = comp not in fusion_called
        for i in instrs:
            if i.opcode == "dot":
                flops += m * _dot_flops(i, shape_of)
            elif i.opcode == "convolution":
                flops += m * _conv_flops(i, shape_of)
            kind = next((k for k in _COLLECTIVES
                         if i.opcode == k or i.opcode.startswith(k + "-start")), None)
            if kind and not i.opcode.endswith("-done"):
                coll[kind]["count"] += m
                coll[kind]["result_bytes"] += m * i.result_bytes
                coll[kind]["operand_bytes"] += m * sum(
                    bytes_of.get(o, 0) for o in i.operands)
            if mem_level and i.opcode not in _ALIAS_OPS and i.opcode != "while":
                hbm_bytes += m * _instr_traffic(i, bytes_of, comps)

    return {
        "flops": flops,
        "hbm_bytes": hbm_bytes,
        "collectives": coll,
        "collective_bytes_total": sum(c["operand_bytes"] for c in coll.values()),
    }


_SLICE_OPS = {"dynamic-slice", "gather", "slice"}


def _instr_traffic(i: Instr, bytes_of: dict[str, int],
                   comps: dict[str, list[Instr]]) -> float:
    """HBM bytes one instruction moves.

    Slicing ops read only the slice, not the whole operand (the backward
    scan reads one layer's saved activations per step, not the full stack);
    dynamic-update-slice writes in place.  Fusion operands consumed *only*
    by slicing ops inside the fused computation are likewise charged at the
    sliced size.
    """
    if i.opcode in _SLICE_OPS:
        return 2.0 * i.result_bytes
    if i.opcode == "dynamic-update-slice":
        upd = bytes_of.get(i.operands[1], 0) if len(i.operands) > 1 else 0
        return 2.0 * upd
    if i.opcode == "scatter":
        upd = bytes_of.get(i.operands[-1], 0) if i.operands else 0
        return i.result_bytes + 2.0 * upd
    if i.opcode == "fusion":
        mcall = re.search(r"calls=%?([\w.\-]+)", i.attrs)
        inner = comps.get(mcall.group(1), []) if mcall else []
        # param index -> sliced-only? and total sliced bytes
        sliced_bytes: dict[int, float] = {}
        sliced_only: dict[int, bool] = {}
        pname_to_idx = {}
        for inst in inner:
            if inst.opcode == "parameter":
                mi = re.match(r"\s*(\d+)", inst.raw_operands)
                if mi:
                    pname_to_idx[inst.name] = int(mi.group(1))
        for inst in inner:
            if inst.opcode == "parameter":
                continue
            for o in inst.operands:
                if o in pname_to_idx:
                    idx = pname_to_idx[o]
                    if inst.opcode in _SLICE_OPS:
                        sliced_bytes[idx] = sliced_bytes.get(idx, 0.0) + inst.result_bytes
                        sliced_only.setdefault(idx, True)
                    else:
                        sliced_only[idx] = False
        total = float(i.result_bytes)
        for k, o in enumerate(i.operands):
            if sliced_only.get(k, False):
                total += sliced_bytes.get(k, 0.0)
            else:
                total += bytes_of.get(o, 0)
        return total
    return float(i.result_bytes + sum(bytes_of.get(o, 0) for o in i.operands))
