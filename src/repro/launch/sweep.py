"""Run the full dry-run sweep: every (arch × shape × mesh) cell as an
isolated subprocess (fresh XLA state per cell), resumable — existing JSON
artifacts are skipped.

  PYTHONPATH=src python -m repro.launch.sweep [--mesh pod multipod] [--jobs 1]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

ARCHS = [
    "qwen3-0.6b", "mamba2-370m", "whisper-tiny", "zamba2-1.2b",
    "qwen2-vl-2b", "glm4-9b", "phi3-medium-14b", "nemotron-4-15b",
    "moonshot-v1-16b-a3b", "qwen3-moe-30b-a3b",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", nargs="+", default=["pod", "multipod"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--archs", nargs="+", default=ARCHS)
    ap.add_argument("--shapes", nargs="+", default=SHAPES)
    ap.add_argument("--timeout", type=int, default=3600)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = [(a, s, m) for m in args.mesh for s in args.shapes
             for a in args.archs]
    done = fail = 0
    t0 = time.time()
    for arch, shape, mesh in cells:
        mesh_name = "pod2x16x16" if mesh == "multipod" else "pod16x16"
        path = os.path.join(args.out, f"{arch}__{shape}__{mesh_name}.json")
        if os.path.exists(path):
            done += 1
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--mesh", mesh, "--out", args.out]
        print(f"[sweep] ({done+fail+1}/{len(cells)}) {arch} x {shape} x {mesh}",
              flush=True)
        try:
            r = subprocess.run(cmd, timeout=args.timeout,
                               capture_output=True, text=True)
            if r.returncode != 0:
                fail += 1
                with open(path + ".err", "w") as f:
                    f.write(r.stdout[-4000:] + "\n---\n" + r.stderr[-8000:])
                print(f"[sweep]   FAILED (see {path}.err)", flush=True)
            else:
                done += 1
        except subprocess.TimeoutExpired:
            fail += 1
            with open(path + ".err", "w") as f:
                f.write("TIMEOUT")
            print("[sweep]   TIMEOUT", flush=True)
    print(f"[sweep] finished: {done} ok, {fail} failed, "
          f"{time.time()-t0:.0f}s", flush=True)


if __name__ == "__main__":
    main()
