import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_EXTRA", "") + " --xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh and extract the roofline terms from the compiled artifact.

MUST be run as a module entry point (python -m repro.launch.dryrun) so the
XLA_FLAGS line above executes before any jax initialization.

Per cell it records to artifacts/dryrun/<arch>__<shape>__<mesh>.json:
  * memory_analysis (per-device argument/output/temp/peak bytes)
  * cost_analysis flops/bytes
  * per-collective byte totals parsed from the optimized HLO
  * analytic MODEL_FLOPS (6*N*D dense / 6*N_active*D MoE) for the
    useful-compute ratio.
"""
import argparse
import json
import re
import sys
import time


def parse_collectives(hlo_text: str) -> dict:
    """Sum operand bytes of collective ops in optimized HLO text.

    Builds a name->bytes table from instruction definitions, then for each
    collective sums the byte sizes of its operands (the data each device
    contributes).  Returns {op_kind: {"count": n, "operand_bytes": b,
    "result_bytes": r}}.
    """
    dtype_bytes = {
        "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
        "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
        "u8": 1, "pred": 1, "c64": 8, "c128": 16,
    }

    def shape_bytes(shape_str: str) -> int:
        # e.g. "f32[16,1024]{1,0}" or "bf16[]" or tuple "(f32[...], s32[...])"
        total = 0
        for m in re.finditer(r"(\w+)\[([\d,]*)\]", shape_str):
            dt, dims = m.group(1), m.group(2)
            if dt not in dtype_bytes:
                continue
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            total += n * dtype_bytes[dt]
        return total

    # First pass: instruction name -> result shape bytes.
    name_bytes: dict[str, int] = {}
    inst_re = re.compile(r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+(\S+?)\(")
    for line in hlo_text.splitlines():
        m = inst_re.match(line)
        if m:
            name_bytes[m.group(1).lstrip("%")] = shape_bytes(m.group(2))

    kinds = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
    out = {k: {"count": 0, "operand_bytes": 0, "result_bytes": 0} for k in kinds}
    for line in hlo_text.splitlines():
        m = inst_re.match(line)
        if not m:
            continue
        op = m.group(3)
        kind = next((k for k in kinds if op == k or op.startswith(k + ".")
                     or op == k + "-start" or op.startswith(k + "-start")), None)
        if kind is None:
            continue
        out[kind]["count"] += 1
        out[kind]["result_bytes"] += shape_bytes(m.group(2))
        # operands: %name tokens inside the parens
        paren = line[line.index(op) + len(op):]
        ops_bytes = 0
        for om in re.finditer(r"%?([\w.\-]+)", paren):
            nb = name_bytes.get(om.group(1))
            if nb:
                ops_bytes += nb
        out[kind]["operand_bytes"] += ops_bytes
    return out


def analytic_model_flops(cfg, kind: str, batch: int, seq: int) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) for train; 2*N*D for inference."""
    n_active = cfg.active_param_count()
    tokens = batch * (seq if kind in ("train", "prefill") else 1)
    mult = 6 if kind == "train" else 2
    return float(mult * n_active * tokens)


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             smoke: bool = False) -> dict:
    import jax
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import (
        cell_is_applicable, input_shardings, input_specs, make_cell,
        make_sharder, make_step_fn,
    )

    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell = make_cell(arch, shape_name, smoke=smoke)
    record: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": cell.kind, "seq": cell.seq, "batch": cell.batch,
        "profile": cell.cfg.sharding_profile,
    }
    ok, why = cell_is_applicable(cell.cfg, shape_name)
    if not ok:
        record["status"] = "SKIP"
        record["skip_reason"] = why
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    sharder = make_sharder(cell, mesh)
    structs, dims = input_specs(cell)
    in_shardings = input_shardings(cell, sharder, structs, dims)
    step = make_step_fn(cell, sharder)

    t0 = time.time()
    with mesh:
        jitted = jax.jit(step, in_shardings=in_shardings)
        lowered = jitted.lower(*structs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    record["memory_analysis"] = {
        k: int(getattr(mem, k))
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes")
        if hasattr(mem, k)
    }
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        # Older JAX returns one dict per computation; newer returns one dict.
        cost = cost[0] if cost else {}
    record["cost_analysis"] = {
        k: float(v) for k, v in dict(cost or {}).items()
        if isinstance(v, (int, float)) and (
            k in ("flops", "bytes accessed", "optimal_seconds")
            or k.startswith("bytes accessed"))
    }
    hlo = compiled.as_text()
    from repro.launch.hlo_cost import analyze as hlo_analyze
    record["hlo_cost"] = hlo_analyze(hlo)   # trip-count-aware (see hlo_cost.py)
    record["collectives_static"] = parse_collectives(hlo)
    record["hlo_chars"] = len(hlo)
    record["model_flops"] = analytic_model_flops(
        cell.cfg, cell.kind, cell.batch, cell.seq)
    record["n_params"] = cell.cfg.param_count()
    record["n_active_params"] = cell.cfg.active_param_count()
    record["lower_s"] = round(t_lower, 2)
    record["compile_s"] = round(t_compile, 2)
    record["n_devices"] = mesh.size
    record["status"] = "OK"
    print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: "
          f"compile {t_compile:.1f}s, "
          f"flops={record['cost_analysis'].get('flops', 0):.3e}", flush=True)
    print(f"  memory_analysis: {record['memory_analysis']}", flush=True)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(
        ("train_4k", "prefill_32k", "decode_32k", "long_500k")))
    ap.add_argument("--mesh", default="pod", choices=("pod", "multipod"))
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CI sanity only)")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    rec = run_cell(args.arch, args.shape, args.mesh == "multipod", args.out,
                   smoke=args.smoke)
    mesh_name = rec["mesh"]
    path = os.path.join(
        args.out, f"{args.arch}__{args.shape}__{mesh_name}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[dryrun] wrote {path} status={rec['status']}")
    return 0 if rec["status"] in ("OK", "SKIP") else 1


if __name__ == "__main__":
    sys.exit(main())
