import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_EXTRA", "") + " --xla_force_host_platform_device_count=512"

"""Pipeline-parallel dry-run proof (optional parallelism mode, DESIGN §5).

Lowers + compiles a GPipe-pipelined qwen3-0.6b train forward+loss on the
multi-pod mesh with the 2 pipeline stages riding the *pod* axis (inter-pod
links carry only microbatch activations — the traffic pattern PP exists
for), batch sharded over the data axis inside each stage.
"""
import json
import sys
import time


def main():
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.configs import get_config
    from repro.launch.mesh import make_production_mesh
    from repro.models.model_zoo import build
    from repro.models.transformer import dense_block
    from repro.parallel.pipeline import gpipe, split_stages
    from repro.launch.hlo_cost import analyze

    cfg = get_config("qwen3-0.6b")
    api = build(cfg)
    mesh = make_production_mesh(multi_pod=True)
    S = mesh.shape["pod"]
    B, L = 256, 4096
    M = 8  # microbatches

    def stage_fn(stage_params, x):
        pos = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), x.shape[:2])
        def body(x, lp):
            y, _, _ = dense_block(cfg, lp, x, positions=pos, sharder=None,
                                  mode="train")
            return y, None
        x, _ = jax.lax.scan(jax.checkpoint(body), x, stage_params)
        return x

    pipe = gpipe(stage_fn, mesh, "pod", n_microbatches=M)

    def step(layers_staged, embed, x_tokens):
        x = jnp.take(embed, x_tokens, axis=0).astype(jnp.bfloat16)
        out = pipe(layers_staged, x)
        return jnp.mean(out.astype(jnp.float32) ** 2)

    shapes = api.shapes(jnp.bfloat16)
    staged = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((S, s.shape[0] // S, *s.shape[1:]), s.dtype),
        shapes["layers"])
    embed = shapes["embed"]
    tokens = jax.ShapeDtypeStruct((B, L), jnp.int32)

    in_sh = (
        jax.tree.map(lambda s: NamedSharding(mesh, P("pod")), staged),
        NamedSharding(mesh, P()),
        NamedSharding(mesh, P(None, None)),
    )
    t0 = time.time()
    with mesh:
        compiled = jax.jit(step, in_shardings=in_sh).lower(
            staged, embed, tokens).compile()
    rec = {
        "mode": "pipeline(pod=2 stages) x data(16)",
        "arch": "qwen3-0.6b", "batch": B, "seq": L, "microbatches": M,
        "compile_s": round(time.time() - t0, 1),
        "memory_analysis": {
            "temp_bytes": int(compiled.memory_analysis().temp_size_in_bytes),
        },
        "hlo_cost": analyze(compiled.as_text()),
        "status": "OK",
    }
    os.makedirs("artifacts/dryrun", exist_ok=True)
    with open("artifacts/dryrun/pipeline__train_4k__pod2x16x16.json", "w") as f:
        json.dump(rec, f, indent=1)
    cp = rec["hlo_cost"]["collectives"]["collective-permute"]
    print(f"[dryrun_pp] OK compile={rec['compile_s']}s "
          f"permute_count={cp['count']} permute_bytes={cp['operand_bytes']:.3e}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
