"""Input ShapeDtypeStruct stand-ins + shardings for every (arch × shape) cell.

Nothing here allocates: inputs are ShapeDtypeStructs and parameter/optimizer
trees come from the declarative tables via param_shapes (eval-shape style).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, get_config
from repro.models.model_zoo import ModelApi, build
from repro.parallel.sharding import Sharder

# The assigned LM shape set (seq_len, global_batch, kind).
SHAPES: dict[str, dict] = {
    "train_4k": {"kind": "train", "seq": 4096, "batch": 256},
    "prefill_32k": {"kind": "prefill", "seq": 32768, "batch": 32},
    "decode_32k": {"kind": "decode", "seq": 32768, "batch": 128},
    "long_500k": {"kind": "decode", "seq": 524288, "batch": 1},
}

# long_500k needs a sub-quadratic path: run only for SSM/hybrid archs
# (attention-free state or periodic attention); skip for pure full-attention
# archs per the assignment (recorded as SKIP rows in the roofline table).
LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")


def cell_is_applicable(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and cfg.family not in LONG_CONTEXT_FAMILIES:
        return False, (
            f"{cfg.family} is full-attention; 500k-token decode has no "
            "sub-quadratic path (DESIGN §4)"
        )
    return True, ""


@dataclasses.dataclass(frozen=True)
class Cell:
    arch: str
    shape_name: str
    cfg: ModelConfig
    api: ModelApi
    kind: str
    seq: int
    batch: int


def make_cell(arch: str, shape_name: str, smoke: bool = False) -> Cell:
    cfg = get_config(arch, smoke=smoke)
    sh = SHAPES[shape_name]
    return Cell(arch=arch, shape_name=shape_name, cfg=cfg, api=build(cfg),
                kind=sh["kind"], seq=sh["seq"], batch=sh["batch"])


def make_sharder(cell: Cell, mesh) -> Sharder:
    data_ways = mesh.shape["data"] * mesh.shape.get("pod", 1)
    return Sharder(
        mesh=mesh,
        profile=cell.cfg.sharding_profile,
        state_over_data=cell.batch < data_ways,
    )


def _batch_specs(cell: Cell, dtype=jnp.bfloat16) -> dict:
    cfg, B, S = cell.cfg, cell.batch, cell.seq
    batch: dict = {
        "tokens": (jax.ShapeDtypeStruct((B, S), jnp.int32), ("batch", "seq")),
    }
    if cell.kind == "train":
        batch["labels"] = (jax.ShapeDtypeStruct((B, S), jnp.int32), ("batch", "seq"))
    if cfg.family == "encdec":
        batch["enc_frames"] = (
            jax.ShapeDtypeStruct((B, cfg.enc_len, cfg.d_model), dtype),
            ("batch", "enc_seq", "embed"),
        )
    if cfg.family == "vlm":
        batch["vision_embeds"] = (
            jax.ShapeDtypeStruct((B, cfg.n_vision_tokens, cfg.d_model), dtype),
            ("batch", "patches", "embed"),
        )
        batch["positions"] = (
            jax.ShapeDtypeStruct((3, B, S), jnp.int32), (None, "batch", "seq"),
        )
    return batch


def split_specs(tagged) -> tuple[dict, dict]:
    """Split {name: (struct, dims)} into (structs, dims)."""
    structs = {k: v[0] for k, v in tagged.items()}
    dims = {k: v[1] for k, v in tagged.items()}
    return structs, dims


def input_specs(cell: Cell, dtype=jnp.bfloat16):
    """Returns (args_structs, args_dims) pytrees for the cell's step fn.

    train  : (state, batch)
    prefill: (params, batch)
    decode : (params, token, cache)
    """
    from repro.train.train_step import state_dims, state_shapes

    if cell.kind == "train":
        batch_structs, batch_dims = split_specs(_batch_specs(cell, dtype))
        return ((state_shapes(cell.api), batch_structs),
                (state_dims(cell.api), batch_dims))

    params_structs = cell.api.shapes(dtype)
    params_dims = cell.api.dims()

    if cell.kind == "prefill":
        batch_structs, batch_dims = split_specs(_batch_specs(cell, dtype))
        return ((params_structs, batch_structs), (params_dims, batch_dims))

    # decode: one token against a cache of size seq (filled to seq-1)
    token = jax.ShapeDtypeStruct((cell.batch,), jnp.int32)
    cache_structs = cell.api.cache_shapes(cell.batch, cell.seq, dtype)
    cache_dims = cell.api.cache_dims()
    return ((params_structs, token, cache_structs),
            (params_dims, ("batch",), cache_dims))


def input_shardings(cell: Cell, sharder: Sharder, structs, dims):
    """NamedShardings for the cell's step args.

    Train-state tensors (fp32 master params, AdamW m/v) get the ZeRO-1 spec
    (additionally sharded over the data axes); everything else follows the
    logical-dims rules.
    """
    import jax
    from repro.parallel.sharding import tree_shardings

    shapes = jax.tree.map(lambda s: s.shape, structs,
                          is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    if cell.kind != "train":
        return tree_shardings(sharder, dims, shapes)

    state_shapes_, batch_shapes = shapes
    state_dims_, batch_dims = dims

    def is_dims(x):
        return isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)

    zero1 = {
        k: jax.tree.map(lambda d, s: sharder.opt_sharding(tuple(d), tuple(s)),
                        state_dims_[k], state_shapes_[k], is_leaf=is_dims)
        for k in ("params", "m", "v")
    }
    zero1["step"] = sharder.sharding((), ())
    batch_sh = tree_shardings(sharder, batch_dims, batch_shapes)
    return (zero1, batch_sh)


def make_step_fn(cell: Cell, sharder: Sharder | None):
    from repro.optim.adamw import AdamWConfig
    from repro.train.serve_step import make_decode_step, make_prefill_step
    from repro.train.train_step import make_train_step

    if cell.kind == "train":
        return make_train_step(cell.api, sharder, AdamWConfig())
    if cell.kind == "prefill":
        return make_prefill_step(cell.api, sharder, max_len=cell.seq)
    return make_decode_step(cell.api, sharder, kv_len=cell.seq - 1)
