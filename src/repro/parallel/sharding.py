"""Logical-axis sharding rules, divisibility-aware.

Every parameter and activation in the model zoo is annotated with *logical*
dim names (e.g. ("vocab", "embed"), ("batch", "seq", "embed")).  A
``Sharder`` resolves logical names to mesh axes through a rule table, with
two safety valves that make one rule set work across all ten architectures
and a fixed 16×16 (or 2×16×16) mesh:

  * divisibility — a dim is only sharded if its size divides evenly by the
    mesh axis size; otherwise it silently falls back to replicated (e.g.
    whisper-tiny's 6 heads on a model=16 axis).
  * profile — "tp" (Megatron tensor parallelism: heads/d_ff/vocab/experts on
    the model axis) or "sp" (sequence parallelism: activations seq-sharded
    on the model axis; used for head counts that cannot shard, per-arch in
    configs).

Batch always shards over ("pod","data") (multi-pod) or ("data",); decode
caches shard their sequence dim over the model axis (flash-decoding-style
partial softmax, SPMD inserts the combine collectives).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# Logical dim -> candidate mesh axes, tried in order; first divisible wins.
_TP_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod+data",),     # composite: shards over pod AND data
    "tokens": ("pod+data",),    # flattened batch*seq (loss chunks)
    "seq": (),                  # replicated in tp profile (per-device full seq)
    "kv_seq": ("model",),       # decode cache: sequence-sharded (flash-decode)
    "embed": (),
    "heads": ("model",),
    "kv_heads": (),             # kv replicated; q heads carry the TP
    "q_per_kv": (),
    "head_dim": (),
    "dff": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "moe_groups": ("pod+data",),
    "expert_dff": (),
    "ssm_heads": ("model",),
    "ssm_headdim": (),
    "ssm_state": (),
    "conv_kernel": (),
    "conv_channels": ("model",),
    "groups": (),
    "enc_seq": (),
    "patches": (),
    "stage": ("pod",),          # pipeline stages ride the pod axis if used
    # Solver-family (learned-stencil) params: the tap dim is tiny (2*ndim),
    # so it replicates; grid rows may shard over data, columns/depth stay
    # local so each shard holds contiguous stencil rows.
    "taps": (),
    "grid_row": ("data",),
    "grid_col": (),
    "grid_depth": (),
}

_SP_RULES: dict[str, tuple[str, ...]] = dict(
    _TP_RULES,
    seq=("model",),
    tokens=("pod+data+model", "pod+data"),
    heads=(),
    dff=(),
    conv_channels=(),
    ssm_heads=(),
    # ZeRO-3-style: weights shard over data on their embed dim and are
    # all-gathered at use (activations' embed dim stays unsharded because
    # batch claims the data axis first — one axis is used at most once).
    embed=("data",),
    # vocab stays model-sharded: the lm_head matmul contracts embed (local)
    # and the xent reduction over vocab psums over the model axis.
)

PROFILES = {"tp": _TP_RULES, "sp": _SP_RULES}


@dataclasses.dataclass(frozen=True)
class Sharder:
    mesh: Mesh
    profile: str = "tp"
    # long_500k / batch=1 decode: batch cannot shard, so spread cache state
    # over the data axis instead (ssm head-dim / kv seq).
    state_over_data: bool = False

    def _axis_size(self, name: str) -> int:
        return self.mesh.shape[name]

    def _resolve(self, dim_name: str, size: int) -> Any:
        rules = dict(PROFILES[self.profile])
        if self.state_over_data:
            rules["ssm_headdim"] = ("data",)
            rules["kv_seq"] = ("model+data", "model")
        for cand in rules.get(dim_name, ()):
            axes = tuple(cand.split("+")) if "+" in cand else (cand,)
            axes = tuple(a for a in axes if a in self.mesh.axis_names)
            if not axes:
                continue
            total = 1
            for a in axes:
                total *= self._axis_size(a)
            if size % total == 0 and size > 0:
                return axes if len(axes) > 1 else axes[0]
        return None

    def spec(self, dims: tuple[str | None, ...], shape: tuple[int, ...]) -> P:
        if len(dims) != len(shape):
            raise ValueError(f"dims {dims} vs shape {shape}")
        taken: set[str] = set()
        entries = []
        for d, s in zip(dims, shape):
            r = None if d is None else self._resolve(d, s)
            # one mesh axis may appear at most once in a spec
            flat = (r,) if isinstance(r, str) else (r or ())
            if r is not None and any(a in taken for a in flat):
                r = None
            if r is not None:
                taken.update(flat)
            entries.append(r)
        return P(*entries)

    def sharding(self, dims: tuple[str | None, ...], shape: tuple[int, ...]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(dims, shape))

    def opt_spec(self, dims: tuple[str | None, ...], shape: tuple[int, ...]) -> P:
        """ZeRO-1 spec for optimizer state / master params: the normal spec,
        plus the largest still-unsharded dim additionally sharded over the
        data axes.  Grads reduce-scatter into it; updated params all-gather
        out — SPMD emits both from the sharding mismatch alone."""
        base = self.spec(dims, shape)
        taken = set()
        for e in base:
            if e is None:
                continue
            taken.update(e if isinstance(e, tuple) else (e,))
        axes = tuple(a for a in ("pod", "data") if a in self.mesh.axis_names
                     and a not in taken)
        if not axes:
            return base
        ways = 1
        for a in axes:
            ways *= self._axis_size(a)
        # largest unsharded dim divisible by the data ways
        cands = [(s, i) for i, s in enumerate(shape)
                 if base[i] is None and s % ways == 0 and s >= ways]
        if not cands:
            return base
        _, idx = max(cands)
        entries = list(base) + [None] * (len(shape) - len(base))
        entries[idx] = axes if len(axes) > 1 else axes[0]
        return P(*entries)

    def opt_sharding(self, dims, shape) -> NamedSharding:
        return NamedSharding(self.mesh, self.opt_spec(dims, shape))

    def constrain(self, x: jax.Array, dims: tuple[str | None, ...]) -> jax.Array:
        """with_sharding_constraint by logical dims (inside jit)."""
        return jax.lax.with_sharding_constraint(x, self.sharding(dims, x.shape))


def tree_shardings(sharder: Sharder, tree_dims, tree_shapes):
    """Map a pytree of logical-dims tuples + shapes to NamedShardings."""
    return jax.tree.map(
        lambda dims, shp: sharder.sharding(tuple(dims), tuple(shp)),
        tree_dims,
        tree_shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
    )
