"""GPipe-style pipeline parallelism via shard_map + ppermute.

Optional parallelism mode (DESIGN §5): layers split into S contiguous stages
whose parameters shard over a mesh axis (the "pod" axis on the multi-pod
mesh — inter-pod links carry only the (mb, seq, d_model) activations once
per tick, the pattern PP exists for).  Microbatches stream through the
classic GPipe schedule: T = M + S - 1 ticks, stage s working on microbatch
t - s at tick t; bubble fraction (S-1)/T.

The implementation is differentiable (ppermute transposes to the reverse
permute), so the same function serves the train step.  It is exercised by
tests on a host mesh and provable-by-compile on the production mesh via
``python -m repro.launch.dryrun_pp``.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def gpipe(
    stage_fn: Callable,        # (stage_params, x (mb, ...)) -> (mb, ...)
    mesh,
    stage_axis: str,
    n_microbatches: int,
):
    """Returns pipelined(params_stacked, x) with params leading dim = S.

    x: (batch, ...) with batch divisible by n_microbatches; params_stacked:
    pytree with leading stage dim S == mesh.shape[stage_axis].
    """
    S = mesh.shape[stage_axis]
    M = n_microbatches

    def local_fn(params_local, x_mb):
        # params_local: stage slice (leading dim 1); x_mb: (M, mb, ...) replicated
        params_local = jax.tree.map(lambda p: p[0], params_local)
        idx = jax.lax.axis_index(stage_axis)
        fwd_perm = [(i, i + 1) for i in range(S - 1)]
        mb_shape = x_mb.shape[1:]
        state = jnp.zeros(mb_shape, x_mb.dtype)
        outs = jnp.zeros_like(x_mb)

        def tick(carry, t):
            state, outs = carry
            # stage 0 pulls microbatch t (clamped); others take the permuted state
            inp = jax.lax.dynamic_index_in_dim(
                x_mb, jnp.clip(t, 0, M - 1), keepdims=False)
            x = jnp.where(idx == 0, inp, state)
            y = stage_fn(params_local, x)
            nxt = jax.lax.ppermute(y, stage_axis, fwd_perm)
            # last stage commits microbatch t-(S-1)
            oi = t - (S - 1)
            commit = (idx == S - 1) & (oi >= 0)
            upd = jax.lax.dynamic_update_index_in_dim(
                outs, y, jnp.clip(oi, 0, M - 1), axis=0)
            outs = jnp.where(commit, upd, outs)
            return (nxt, outs), None

        (state, outs), _ = jax.lax.scan(
            tick, (state, outs), jnp.arange(M + S - 1))
        # replicate the last stage's outputs to every stage
        mask = (idx == S - 1).astype(outs.dtype)
        outs = jax.lax.psum(outs * mask, stage_axis)
        return outs

    def pipelined(params_stacked, x):
        B = x.shape[0]
        if B % M:
            raise ValueError(f"batch {B} not divisible by microbatches {M}")
        x_mb = x.reshape(M, B // M, *x.shape[1:])
        from repro.parallel.halo import shard_map_compat
        fn = shard_map_compat(local_fn, mesh, (P(stage_axis), P()), P())
        out = fn(params_stacked, x_mb)
        return out.reshape(B, *x.shape[1:])

    return pipelined


def split_stages(stacked_params, n_stages: int):
    """(L, ...) stacked layer params -> (S, L/S, ...) stage-stacked."""
    def resh(p):
        L = p.shape[0]
        if L % n_stages:
            raise ValueError(f"{L} layers not divisible into {n_stages} stages")
        return p.reshape(n_stages, L // n_stages, *p.shape[1:])
    return jax.tree.map(resh, stacked_params)
