"""Halo exchange over a device grid via shard_map + lax.ppermute.

The WSE's fabric places grid tiles on a 2D mesh of PEs with single-hop
neighbour links; a TPU pod's ICI torus is the same topology one level up.
This module exchanges radius-r halos (rows then columns — the second phase
carries the corners) with *non-wrapping* permutes: edge devices receive
zeros, matching the zero-padding semantics of the stencil oracle.

Deep halos are the communication-avoiding trick of the wafer-scale scaling
papers (Rocki et al., Jacquelin et al.): exchanging an ``r*k``-deep halo
once buys ``k`` local stencil iterations before the next exchange — the
valid region of the augmented tile shrinks by ``r`` per local step
(trapezoid-style), so ``ppermute`` rounds drop by ``k`` at the price of rim
recompute.  ``core/distributed.py`` builds that fused stepper on top of
:func:`exchange_halo_2d`; the depth is bounded by the local tile extent
(a device can only forward what it owns — a single exchange phase reaches
one neighbour deep).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def shard_map_compat(f, mesh, in_specs, out_specs):
    """shard_map across JAX versions: ``jax.shard_map(check_vma=)`` is the
    new spelling, ``jax.experimental.shard_map.shard_map(check_rep=)`` the
    old one (<= 0.4.x)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def _shift_perm(n: int, direction: int) -> list[tuple[int, int]]:
    """Permutation sending shard i -> i+direction (non-wrapping)."""
    if direction > 0:
        return [(i, i + 1) for i in range(n - 1)]
    return [(i + 1, i) for i in range(n - 1)]


def exchange_1d(xl: jnp.ndarray, axis_name: str, n: int, dim: int, r: int = 1):
    """Gather r-deep halos along ``dim`` from both neighbours on ``axis_name``.

    Returns (lo_halo, hi_halo): each has extent r along ``dim``; zeros at the
    global boundary (non-wrapping permute).  ``r`` may exceed the stencil
    radius (deep halos for temporal fusion) but never the local extent — a
    single exchange phase only reaches the adjacent shard.
    """
    size = xl.shape[dim]
    if r > size:
        raise ValueError(
            f"halo depth {r} exceeds the local extent {size} along dim {dim} "
            f"— one exchange phase can only fetch what the adjacent shard "
            f"owns (shrink the fuse depth or the device mesh)")
    hi_edge = jax.lax.slice_in_dim(xl, size - r, size, axis=dim)
    lo_edge = jax.lax.slice_in_dim(xl, 0, r, axis=dim)
    # neighbour i-1's high edge arrives as our low halo
    lo_halo = jax.lax.ppermute(hi_edge, axis_name, _shift_perm(n, +1))
    hi_halo = jax.lax.ppermute(lo_edge, axis_name, _shift_perm(n, -1))
    return lo_halo, hi_halo


def exchange_halo_2d(xl: jnp.ndarray, row_axis: str, col_axis: str,
                     n_row: int, n_col: int, r: int = 1) -> jnp.ndarray:
    """xl: (..., h, w) local tile -> (..., h+2r, w+2r) with halos filled.

    Phase 1 exchanges columns, phase 2 exchanges rows of the column-augmented
    tile so corner halos ride along — supports any radius-r box stencil (and
    any deep-halo depth ``r <= min(h, w)``).  Four ``ppermute`` rounds per
    call: two directions per axis.
    """
    wdim = xl.ndim - 1
    hdim = xl.ndim - 2
    left, right = exchange_1d(xl, col_axis, n_col, wdim, r)
    xw = jnp.concatenate([left, xl, right], axis=wdim)
    top, bot = exchange_1d(xw, row_axis, n_row, hdim, r)
    return jnp.concatenate([top, xw, bot], axis=hdim)
