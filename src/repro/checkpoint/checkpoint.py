"""Mesh-shape-independent checkpointing with async flush.

Checkpoints store the *logical* (unsharded) arrays as one .npz per step plus
a manifest; on restore the arrays are placed under whatever sharding the
*current* mesh dictates — so a run checkpointed on 512 chips restarts on 256
(or 8) unchanged: the elastic property tests/test_checkpoint.py asserts.

At 10B+ scale a real deployment writes per-shard files through a storage
fanout; the logical format here keeps the semantics (reshard-on-load) that
the fault-tolerance layer needs, on one host.  Writes go to a temp file then
os.replace — a crash mid-write never corrupts the latest checkpoint.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any

import jax
import numpy as np

from repro.core.stencil import WeightField

_SEP = "/"
# Key suffix marking a leaf that was a WeightField (solver-family stencil
# params); _unflatten re-wraps so restored trees round-trip structurally.
_WF_MARK = "%wf"


def _flatten(tree: Any, prefix: str = "") -> dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{_SEP}{k}" if prefix else str(k)))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{_SEP}{i}" if prefix else str(i)))
    elif isinstance(tree, WeightField):
        out[prefix + _WF_MARK] = np.asarray(jax.device_get(tree.values))
    else:
        out[prefix] = np.asarray(jax.device_get(tree))
    return out


def _unflatten(flat: dict[str, np.ndarray]) -> Any:
    tree: dict = {}
    for key, val in flat.items():
        if key.endswith(_WF_MARK):
            key = key[: -len(_WF_MARK)]
            val = WeightField(val)
        parts = key.split(_SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return tree


class Checkpointer:
    """save(step, tree) / restore_latest() with an async writer thread."""

    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt_{step:08d}.npz")

    def save(self, step: int, tree: Any, blocking: bool = True) -> None:
        flat = _flatten(tree)  # device_get happens on the caller thread

        def write():
            # np.savez appends ".npz" unless the name already ends with it
            tmp = self._path(step) + ".tmp.npz"
            np.savez(tmp, **flat)
            os.replace(tmp, self._path(step))
            with open(os.path.join(self.dir, "manifest.json"), "w") as f:
                json.dump({"latest_step": step}, f)
            self._gc()

        if blocking:
            write()
        else:
            self.wait()
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        ckpts = sorted(f for f in os.listdir(self.dir) if f.startswith("ckpt_")
                       and f.endswith(".npz"))
        for old in ckpts[: -self.keep]:
            os.remove(os.path.join(self.dir, old))

    def latest_step(self) -> int | None:
        m = os.path.join(self.dir, "manifest.json")
        if not os.path.exists(m):
            return None
        with open(m) as f:
            return json.load(f)["latest_step"]

    def restore(self, step: int, shardings: Any | None = None) -> Any:
        self.wait()
        with np.load(self._path(step)) as z:
            flat = {k: z[k] for k in z.files}
        tree = _unflatten(flat)
        if shardings is not None:
            # is_leaf keeps WeightFields whole (they are pytree nodes, the
            # shardings tree has a single sharding at their position);
            # device_put broadcasts that sharding over the wrapped array.
            tree = jax.tree.map(
                lambda arr, sh: jax.device_put(arr, sh), tree, shardings,
                is_leaf=lambda x: isinstance(x, WeightField))
        return tree

    def restore_latest(self, shardings: Any | None = None) -> tuple[int, Any] | None:
        step = self.latest_step()
        if step is None:
            return None
        return step, self.restore(step, shardings)
