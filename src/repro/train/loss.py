"""Vocab-sharded, sequence-chunked softmax cross-entropy.

The full logits tensor (tokens × vocab) never materializes:
  * the lm_head is vocab-sharded over the model axis, so each shard holds a
    (chunk, V/tp) logits block; the max / sum-exp reductions over vocab make
    SPMD emit the small combine collectives;
  * a rematted lax.scan over token chunks bounds the live block to
    (tokens/n_chunks, V/tp) fp32 — and the backward recomputes each chunk's
    logits instead of storing them.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def chunked_xent(
    lm_head: jnp.ndarray,
    hidden: jnp.ndarray,
    labels: jnp.ndarray,
    *,
    sharder=None,
    n_chunks: int = 8,
    valid_vocab: int | None = None,
) -> jnp.ndarray:
    """lm_head: (V, D); hidden: (B, S, D); labels: (B, S) -> mean nll (fp32).

    valid_vocab masks padded vocab rows (ModelConfig.padded_vocab) to -inf.
    """
    B, S, D = hidden.shape
    V = lm_head.shape[0]
    T = B * S
    h = hidden.reshape(T, D)
    y = labels.reshape(T)
    if T % n_chunks:
        n_chunks = next(c for c in range(n_chunks, 0, -1) if T % c == 0)
    hc = h.reshape(n_chunks, T // n_chunks, D)
    yc = y.reshape(n_chunks, T // n_chunks)
    if sharder is not None:
        # chunk token dim keeps the activation sharding (batch — and seq too
        # in the sp profile); vocab rides the model axis where free
        hc = sharder.constrain(hc, (None, "tokens", "embed"))
        yc = sharder.constrain(yc, (None, "tokens"))

    def body(acc, inp):
        hx, yx = inp
        logits = jnp.einsum("td,vd->tv", hx, lm_head,
                            preferred_element_type=jnp.float32)
        if sharder is not None:
            logits = sharder.constrain(logits, ("tokens", "vocab"))
        if valid_vocab is not None and valid_vocab < V:
            ids = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
            logits = jnp.where(ids < valid_vocab, logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(yx, V, dtype=jnp.float32)
        correct = jnp.sum(logits * onehot, axis=-1)
        return acc + jnp.sum(lse - correct), None

    acc, _ = jax.lax.scan(jax.checkpoint(body), jnp.zeros((), jnp.float32),
                          (hc, yc))
    return acc / T
