"""The jit-able training step: bf16 compute off fp32 master params, chunked
vocab-sharded loss, AdamW update.  ``make_train_step`` returns the function
plus the in/out sharding trees the launcher (and dry-run) feed to jax.jit.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.model_zoo import ModelApi
from repro.optim.adamw import AdamWConfig, apply_update, init_state
from repro.parallel.sharding import Sharder

MOE_AUX_WEIGHT = 0.01


def cast_tree(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree,
    )


def loss_fn(api: ModelApi, params_f32, batch, sharder: Sharder | None,
            compute_dtype=jnp.bfloat16):
    params = cast_tree(params_f32, compute_dtype)
    hidden, aux = api.forward(params, batch, sharder=sharder)
    from repro.train.loss import chunked_xent
    nll = chunked_xent(params["lm_head"], hidden, batch["labels"],
                       sharder=sharder, valid_vocab=api.cfg.vocab_size)
    loss = nll + MOE_AUX_WEIGHT * aux
    return loss, {"nll": nll, "aux": aux}


def make_train_step(api: ModelApi, sharder: Sharder | None,
                    opt: AdamWConfig, compute_dtype=jnp.bfloat16,
                    loss=None):
    """``loss`` defaults per family: solver layers get the steady-state MSE
    (they compute in f32 — convergence thresholds are meaningless in bf16),
    everything else the chunked LM cross-entropy above."""
    if loss is None:
        if getattr(api.cfg, "family", None) == "solver":
            from repro.models.solver_layer import solver_loss_fn
            loss = solver_loss_fn
        else:
            loss = loss_fn

    def train_step(state, batch):
        (loss_val, parts), grads = jax.value_and_grad(
            lambda p: loss(api, p, batch, sharder, compute_dtype),
            has_aux=True,
        )(state["params"])
        new_state, opt_metrics = apply_update(state, grads, opt)
        metrics = {"loss": loss_val, **parts, **opt_metrics}
        return new_state, metrics

    return train_step


def init_train_state(api: ModelApi, key):
    params = api.init(key, jnp.float32)   # fp32 master
    return init_state(params)


def state_dims(api: ModelApi):
    pdims = api.dims()
    return {
        "params": pdims,
        "m": pdims,
        "v": pdims,
        "step": (),
    }


def state_shapes(api: ModelApi):
    shapes = api.shapes(jnp.float32)
    zeros = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), shapes)
    return {
        "params": shapes,
        "m": zeros,
        "v": jax.tree.map(lambda s: s, zeros),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
