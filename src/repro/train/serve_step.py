"""Serving steps: prefill (prompt -> cache + first logits) and decode
(one token against the cache).  Mirrors the train step's structure so the
dry-run can lower either per shape kind.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.model_zoo import ModelApi
from repro.parallel.sharding import Sharder


def make_prefill_step(api: ModelApi, sharder: Sharder | None, max_len: int):
    def prefill_step(params, batch):
        from repro.models.transformer import mask_pad_logits
        last_hidden, cache = api.prefill(params, batch, max_len,
                                         sharder=sharder)
        logits = jnp.einsum("bd,vd->bv", last_hidden, params["lm_head"],
                            preferred_element_type=jnp.float32)
        if sharder is not None:
            logits = sharder.constrain(logits, (None, "vocab"))
        logits = mask_pad_logits(logits, api.cfg)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, cache

    return prefill_step


def make_decode_step(api: ModelApi, sharder: Sharder | None, kv_len: int):
    """kv_len is static per compiled step (bucketed in a real server)."""
    def decode_step(params, token, cache):
        logits, new_cache = api.decode_step(params, token, cache, kv_len,
                                            sharder=sharder)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, new_cache

    return decode_step


def greedy_generate(api: ModelApi, params, batch, *, steps: int, max_len: int,
                    sharder: Sharder | None = None):
    """Reference generation loop (prefill + ``steps`` greedy decodes)."""
    prefill = make_prefill_step(api, sharder, max_len)
    token, cache = prefill(params, batch)
    S = batch["tokens"].shape[1]
    out = [token]
    for i in range(steps - 1):
        step = make_decode_step(api, sharder, S + i)
        token, cache = step(params, token, cache)
        out.append(token)
    return jnp.stack(out, axis=1)
