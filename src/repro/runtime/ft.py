"""Fault-tolerant training runtime.

Production posture for 1000+ nodes (DESIGN §5):
  * step-granular checkpointing (async flush, atomic replace, keep-N)
  * restart-from-latest on any failure — checkpoints are mesh-shape
    independent, so the restarted job may run on a different device count
    (elastic): tests assert bit-equal training trajectories across a
    kill/restart and across a device-count change.
  * failure injection for testing (raise at a chosen step)
  * straggler mitigation: per-step wall-time EWMA + p-quantile tracking;
    steps slower than ``straggler_factor``× the EWMA are logged and counted
    (on a real cluster this feeds the scheduler's node-eviction policy —
    single-host here, so the driver records rather than evicts).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator

from repro.checkpoint.checkpoint import Checkpointer


@dataclasses.dataclass
class FTConfig:
    checkpoint_dir: str
    checkpoint_every: int = 50
    keep: int = 3
    async_save: bool = True
    straggler_factor: float = 3.0
    fail_at_step: int | None = None     # failure injection (tests)


@dataclasses.dataclass
class StepStats:
    step: int
    seconds: float
    is_straggler: bool
    metrics: dict


class InjectedFailure(RuntimeError):
    pass


def run_training(
    train_step: Callable[[Any, Any], tuple[Any, dict]],
    init_state: Callable[[], Any],
    batch_for_step: Callable[[int], Any],
    n_steps: int,
    ft: FTConfig,
    state_shardings: Any | None = None,
    on_step: Callable[[StepStats], None] | None = None,
) -> tuple[Any, list[StepStats]]:
    """Drive training with checkpoint/restart.  Returns (state, stats).

    Restart semantics: if a checkpoint exists in ft.checkpoint_dir, training
    resumes from it (the caller decides whether that is a cold start or a
    post-failure restart — the driver does not care, which is the point).
    """
    ckpt = Checkpointer(ft.checkpoint_dir, keep=ft.keep)
    restored = ckpt.restore_latest(state_shardings)
    if restored is not None:
        start_step, state = restored
        start_step = int(start_step)
    else:
        state = init_state()
        start_step = 0

    stats: list[StepStats] = []
    ewma = None
    for step in range(start_step, n_steps):
        if ft.fail_at_step is not None and step == ft.fail_at_step:
            ckpt.wait()
            raise InjectedFailure(f"injected failure at step {step}")
        batch = batch_for_step(step)
        t0 = time.perf_counter()
        state, metrics = train_step(state, batch)
        # materialize to time the step honestly
        import jax
        jax.block_until_ready(metrics.get("loss", metrics))
        dt = time.perf_counter() - t0
        ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
        straggler = dt > ft.straggler_factor * ewma and step > start_step + 2
        st = StepStats(step, dt, straggler,
                       {k: float(v) for k, v in metrics.items()})
        stats.append(st)
        if on_step:
            on_step(st)
        if (step + 1) % ft.checkpoint_every == 0 or step + 1 == n_steps:
            ckpt.save(step + 1, state, blocking=not ft.async_save)
    ckpt.wait()
    return state, stats
