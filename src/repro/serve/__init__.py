"""Stencil-solve serving — the request-facing layer over the solver stack.

``serve.engine`` turns :class:`core.solver.Solver` into a service: an async
request queue with admission control that coalesces compatible pending
solves into one batched ``solve()`` (per-instance convergence freezing makes
a batched solve reproduce each request solved alone) and routes every plan
through the shared :class:`core.plan_cache.PlanCache`.  The dormant LM-side
substrate (``launch/serve.py``) stays as-is; this is the stencil entry
point.
"""
from repro.serve.engine import EngineStats, RejectedError, ServingEngine

__all__ = ["EngineStats", "RejectedError", "ServingEngine"]
