"""Async stencil-solve serving engine: coalescing, admission control, fan-out.

The serving shape of the paper's workflow is compile-once/solve-many: the
compiled solver loop is the expensive artifact, and throughput comes from
streaming as many requests as possible through each compiled dispatch.  The
engine implements that in three layers:

* **Admission control** — a bounded queue.  ``submit`` rejects immediately
  with :class:`RejectedError` (carrying a reason) once ``max_queue``
  requests are pending, so overload produces fast feedback instead of
  unbounded latency.

* **Coalescing** — the dispatcher drains the queue into batches of up to
  ``max_batch`` requests, waiting at most ``max_wait`` seconds for
  stragglers, then groups them by compatibility: same operator (spec), grid
  shape, dtype, Dirichlet value, and convergence configuration.  Each group
  runs as ONE batched ``solve()`` on the shared plan cache — per-request
  ``x0`` (and optional per-request ``source``) stack on the instance axis,
  and per-instance convergence freezing guarantees each request gets exactly
  the result it would have gotten alone.  While a batch executes on device,
  new arrivals accumulate in the queue, so sustained load batches naturally.

* **Fan-out** — each request's future resolves to its own per-instance
  :class:`core.solver.SolveResult` (its slice of the field, iteration count,
  convergence flag, residual history column).

``method="multigrid"`` routes a request through the same cache's
:meth:`PlanCache.multigrid` entries (hierarchies don't batch — they run
serially within the dispatch) and resolves to an ``MGResult``.

Typical use::

    async with ServingEngine(max_batch=16, max_wait=0.01) as eng:
        results = await asyncio.gather(
            *(eng.submit(spec, x0, bc=1.0, rtol=1e-6) for x0 in problems))
"""
from __future__ import annotations

import asyncio
import dataclasses
from concurrent.futures import ThreadPoolExecutor

import jax.numpy as jnp
import numpy as np

from repro.core.plan_cache import PlanCache, default_plan_cache
from repro.core.solver import SolveResult
from repro.core.stencil import StencilSpec


class RejectedError(RuntimeError):
    """A request was refused admission; ``reason`` says why."""

    def __init__(self, reason: str):
        super().__init__(reason)
        self.reason = reason


@dataclasses.dataclass
class EngineStats:
    """Counters surfaced on :attr:`ServingEngine.stats`."""

    accepted: int = 0
    rejected: int = 0
    completed: int = 0
    failed: int = 0
    batches: int = 0
    coalesced: int = 0    # requests that shared a batched dispatch
    max_batch: int = 0

    @property
    def mean_batch(self) -> float:
        return self.completed / self.batches if self.batches else 0.0

    def as_dict(self) -> dict:
        return {"accepted": self.accepted, "rejected": self.rejected,
                "completed": self.completed, "failed": self.failed,
                "batches": self.batches, "coalesced": self.coalesced,
                "max_batch": self.max_batch, "mean_batch": self.mean_batch}


@dataclasses.dataclass
class _Request:
    spec: StencilSpec
    x0: object
    source: object
    method: str
    group_key: tuple
    solver_kwargs: dict
    future: asyncio.Future


class ServingEngine:
    """Coalescing solve server over a shared :class:`PlanCache`.

    Args:
      cache: plan cache to route through (default: the process-wide
        :func:`default_plan_cache`).
      max_batch: most requests one batched dispatch carries.
      max_wait: seconds the dispatcher waits for stragglers after the first
        request of a batch arrives.
      max_queue: pending-request bound; submissions beyond it are rejected.

    Use as an async context manager, or call :meth:`start`/:meth:`stop`.
    Blocking JAX work runs on a single worker thread so the event loop stays
    responsive while solves execute.
    """

    def __init__(self, cache: PlanCache | None = None, *, max_batch: int = 16,
                 max_wait: float = 0.01, max_queue: int = 64):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        self.cache = cache if cache is not None else default_plan_cache()
        self.max_batch = int(max_batch)
        self.max_wait = float(max_wait)
        self.max_queue = int(max_queue)
        self.stats = EngineStats()
        self._queue: asyncio.Queue[_Request] | None = None
        self._pending = 0          # admitted but not yet resolved
        self._task: asyncio.Task | None = None
        self._pool = ThreadPoolExecutor(max_workers=1,
                                        thread_name_prefix="stencil-serve")
        self._paused: asyncio.Event | None = None

    # -- lifecycle ---------------------------------------------------------

    @property
    def running(self) -> bool:
        return self._task is not None and not self._task.done()

    async def start(self) -> "ServingEngine":
        if self.running:
            return self
        self._queue = asyncio.Queue()
        self._paused = asyncio.Event()
        self._paused.set()
        self._task = asyncio.get_running_loop().create_task(self._dispatch())
        return self

    async def stop(self, drain: bool = True) -> None:
        """Stop dispatching.  ``drain=True`` finishes queued work first;
        otherwise queued requests are rejected."""
        if not self.running:
            return
        if drain:
            self._paused.set()
            while self._pending:
                await asyncio.sleep(0.005)
        self._task.cancel()
        try:
            await self._task
        except asyncio.CancelledError:
            pass
        while not self._queue.empty():
            req = self._queue.get_nowait()
            if not req.future.done():
                self.stats.rejected += 1
                req.future.set_exception(RejectedError("engine stopped"))
        self._task = None

    async def __aenter__(self) -> "ServingEngine":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop(drain=exc[0] is None)

    def pause(self) -> None:
        """Hold the dispatcher (requests queue up; admission still applies)."""
        self._paused.clear()

    def resume(self) -> None:
        self._paused.set()

    # -- submission --------------------------------------------------------

    async def submit(
        self,
        spec: StencilSpec,
        x0,
        *,
        bc: float = 0.0,
        source=None,
        method: str = "jacobi",
        backend: str = "auto",
        dtype=jnp.float32,
        rtol: float | None = 1e-5,
        atol: float | None = 0.0,
        norm: str = "l2",
        check_every: int | None = None,
        max_iters: int = 10_000,
        **method_kwargs,
    ):
        """Queue one solve; awaits its per-request result.

        ``x0`` is one bare grid (requests batch on the instance axis — to
        solve a pre-batched stack, submit its instances individually and
        gather).  ``bc`` must be a scalar (the group's shared Dirichlet
        value); ``source`` may differ per request.  ``method="jacobi"``
        resolves to a :class:`SolveResult`, ``method="multigrid"`` to an
        ``MGResult`` (extra ``method_kwargs`` reach the ``Multigrid``
        constructor).  Raises :class:`RejectedError` when the queue is full
        or the engine is stopped.
        """
        if method not in ("jacobi", "multigrid"):
            raise ValueError(f"unknown method {method!r}")
        if not isinstance(bc, (int, float)):
            raise ValueError("engine requests need a scalar Dirichlet value")
        if not self.running:
            raise RejectedError("engine is not running")
        if self._pending >= self.max_queue:
            self.stats.rejected += 1
            raise RejectedError(
                f"queue full ({self._pending} pending >= max_queue="
                f"{self.max_queue})")

        x0 = np.asarray(x0)
        if x0.ndim != spec.ndim:
            raise ValueError(
                f"x0 must be one bare {spec.ndim}D grid, got shape "
                f"{x0.shape}")
        grid_shape = tuple(x0.shape)
        cfg = (rtol, atol, norm, check_every, max_iters)
        if method == "multigrid":
            kwargs = dict(bc=float(bc), backend=backend, rtol=rtol,
                          atol=atol, norm=norm, dtype=dtype, **method_kwargs)
            group_key = ("multigrid", spec, grid_shape, str(dtype),
                         float(bc), cfg,
                         tuple(sorted(method_kwargs.items())))
        else:
            if method_kwargs:
                raise ValueError(
                    f"unknown arguments for method='jacobi': "
                    f"{sorted(method_kwargs)}")
            kwargs = dict(dtype=dtype, backend=backend, bc=float(bc),
                          rtol=rtol, atol=atol, norm=norm,
                          check_every=check_every, max_iters=max_iters)
            group_key = ("jacobi", spec, grid_shape, str(dtype), backend,
                         float(bc), cfg)

        fut = asyncio.get_running_loop().create_future()
        req = _Request(spec=spec, x0=x0, source=source, method=method,
                       group_key=group_key, solver_kwargs=kwargs, future=fut)
        self.stats.accepted += 1
        self._pending += 1
        fut.add_done_callback(self._resolved)
        self._queue.put_nowait(req)
        return await fut

    def _resolved(self, _fut) -> None:
        self._pending -= 1

    # -- dispatch loop -----------------------------------------------------

    async def _dispatch(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            first = await self._queue.get()
            # A pause taken while we were blocked on the queue holds the
            # dequeued request here until resume.
            await self._paused.wait()
            batch = [first]
            deadline = loop.time() + self.max_wait
            while len(batch) < self.max_batch:
                timeout = deadline - loop.time()
                if timeout <= 0:
                    break
                try:
                    batch.append(await asyncio.wait_for(
                        self._queue.get(), timeout))
                except asyncio.TimeoutError:
                    break
            while len(batch) < self.max_batch and not self._queue.empty():
                batch.append(self._queue.get_nowait())

            groups: dict[tuple, list[_Request]] = {}
            for req in batch:
                groups.setdefault(req.group_key, []).append(req)
            for group in groups.values():
                try:
                    results = await loop.run_in_executor(
                        self._pool, self._run_group, group)
                except Exception as e:
                    self.stats.failed += len(group)
                    for req in group:
                        if not req.future.done():
                            req.future.set_exception(e)
                else:
                    self.stats.batches += 1
                    self.stats.completed += len(group)
                    self.stats.max_batch = max(self.stats.max_batch,
                                               len(group))
                    if len(group) > 1:
                        self.stats.coalesced += len(group)
                    for req, res in zip(group, results):
                        if not req.future.done():
                            req.future.set_result(res)

    # -- blocking group execution (worker thread) --------------------------

    def _run_group(self, group: list[_Request]) -> list:
        req0 = group[0]
        if req0.method == "multigrid":
            mg = self.cache.multigrid(req0.spec, tuple(req0.x0.shape),
                                      **req0.solver_kwargs)
            return [mg.solve(jnp.asarray(req.x0)) for req in group]

        solver = self.cache.solver(req0.spec, tuple(req0.x0.shape),
                                   **req0.solver_kwargs)
        # Pad the instance axis to the next power of two (with copies of the
        # first request) so one compiled loop signature serves every batch
        # size in its bucket — per-instance freezing keeps results exact and
        # the padding instances converge with their original.
        b = len(group)
        n_pad = (1 << (b - 1).bit_length()) - b
        xb = jnp.stack([jnp.asarray(req.x0) for req in group]
                       + [jnp.asarray(req0.x0)] * n_pad)
        source = None
        if any(req.source is not None for req in group):
            zeros = np.zeros(req0.x0.shape, np.float32)
            stack = [req.source if req.source is not None else zeros
                     for req in group]
            stack += [stack[0]] * n_pad
            source = jnp.stack([jnp.asarray(s) for s in stack])
        res = solver.solve(xb, source=source)
        return [
            SolveResult(
                x=res.x[i], iterations=int(res.iterations[i]),
                converged=bool(res.converged[i]),
                residual=float(res.residual[i]),
                residual_history=res.residual_history[:, i],
                backend=res.backend, fuse=res.fuse,
                check_every=res.check_every, wall_seconds=res.wall_seconds,
                est_seconds=res.est_seconds, costs=res.costs)
            for i in range(len(group))
        ]
