"""The paper's *delivered performance* metric (Eq. 1) and FLOP accounting.

    delivered = problemSize * stencilFLOP * iterations / time

``stencilFLOP`` counts the FLOPs the *encoding* implies per output element —
including the redundant ones the paper highlights in §4:

  useful (2D Laplace)     7        4 mul + 3 add
  conv encoding (3×3)     17       full window: 9 mul + 8 add
  dense encoding          2N-1     8191 for N=4096 (X=Y=64)
  mask trick (+BC)        +2       one mul + one add per element

It is a *relative* metric (the paper's framing): it lets encodings and
hardware be compared, not absolute efficiency measured.  We additionally
report useful-FLOPs throughput ("useful performance") — possible here
because, unlike the TF black box, our FLOP accounting is analytic.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.stencil import StencilSpec


@dataclasses.dataclass(frozen=True)
class DeliveredPerf:
    problem_size: int          # total elements processed (N * steps)
    stencil_flop: int          # per-element FLOPs the encoding performs
    useful_flop: int           # per-element FLOPs that contribute (paper: 7)
    iterations: int
    seconds: float

    @property
    def delivered_gflops(self) -> float:
        return self.problem_size * self.stencil_flop * self.iterations / self.seconds / 1e9

    @property
    def useful_gflops(self) -> float:
        return self.problem_size * self.useful_flop * self.iterations / self.seconds / 1e9

    @property
    def waste_ratio(self) -> float:
        """delivered/useful — 1.0 is a perfect encoding (direct stencil)."""
        return self.stencil_flop / self.useful_flop

    def row(self, label: str) -> str:
        return (
            f"{label},{self.problem_size},{self.iterations},{self.seconds:.4f},"
            f"{self.delivered_gflops:.2f},{self.useful_gflops:.2f},{self.waste_ratio:.1f}"
        )


def encoding_flops_per_point(
    spec: StencilSpec,
    encoding: str,
    n_total: int | None = None,
    mask_trick: bool = True,
) -> int:
    """Per-element FLOP count for an encoding, per the paper's §4 accounting."""
    extra = 2 if mask_trick else 0  # out*mask + bc
    if encoding == "dense":
        if n_total is None:
            raise ValueError("dense encoding needs n_total")
        return spec.delivered_flops_per_point_dense(n_total)  # matrix already holds BCs
    if encoding == "conv":
        return spec.delivered_flops_per_point_conv() + extra
    if encoding == "conv3d_channels":
        # Banded channel matrix: every output channel convolves all Z input
        # channels through a kh*kw window -> Z * window MACs per element.
        if n_total is None:
            raise ValueError("conv3d_channels needs n_total = Z (depth)")
        window = int(np.prod(spec.footprint[1:]))
        return 2 * n_total * window - 1 + extra
    if encoding == "direct":
        return spec.useful_flops_per_point + (extra if mask_trick else 0)
    raise ValueError(f"unknown encoding {encoding!r}")
