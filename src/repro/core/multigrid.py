"""Geometric multigrid V-cycle composed from the stencil dispatch stack.

The paper's wafer solves are plain Jacobi iteration — thousands of sweeps
whose convergence stalls as the grid grows (the smooth error modes contract
like ``1 - O(h^2)``).  Multigrid is the textbook answer: smooth the
high-frequency error on the fine grid, restrict the residual to a coarser
grid where the remaining smooth error is high-frequency again, recurse, and
prolongate the correction back up.  A V-cycle costs a small constant number
of fine-grid-equivalent stencil sweeps yet contracts *all* error modes by a
grid-independent factor.

Everything here is built from the repo's own primitives so the whole
hierarchy rides the PR 1 dispatcher:

  * smoothing on every level is a 1-iteration :func:`make_plan` of the
    level's spec (any backend, ``backend="auto"`` included);
  * restriction (full weighting) and prolongation (linear interpolation) are
    themselves ``StencilSpec``s — :func:`restriction_spec` /
    :func:`prolongation_spec` — applied through raw (``bc=None``) plans,
    with the even-index sampling / zero-stuffing around them;
  * the coarse-level operator is the re-discretized spec
    (:func:`coarsen_spec`): scalar taps transfer unchanged, per-cell weight
    fields are injected onto the coarse grid.

Formulation.  The engine solves the Jacobi fixed point ``u = S(u)`` with a
Dirichlet shell, exactly like ``core.solver.solve``.  The error equation is
carried in the same fixed-point form: on coarse levels the plan's BC is 0
and the restricted residual enters as an additive per-cell source ``g``
(``u <- mask*(S(u) + g) + bc``).  The residual of the Jacobi form is the
``h^2``-scaled residual of the underlying second-order operator, so each
restriction multiplies it by ``(2h/h)^2 = 4`` before it becomes the coarse
right-hand side.

Red-black Gauss-Seidel (:func:`red_black_step`) is provided both as the
default smoother and as a standalone sweep: two masked half-sweeps, each a
full stencil application that commits only one parity class.  For star
stencils (all the paper's operators) this is exact Gauss-Seidel, and it is
the classic wafer-friendly smoother — each half-sweep is as data-parallel
as Jacobi.

Work accounting uses *fine-grid work units*: one unit is one stencil sweep
over the finest grid, so a level-``l`` sweep costs ``n_l / n_0`` units and a
plain Jacobi iteration costs exactly 1.  This is the currency the
``BENCH_stencil.json`` multigrid section and the ``>= 10x vs Jacobi``
acceptance test are written in.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.boundary import BoundaryMode, DirichletBC
from repro.core.plan import StencilPlan, make_plan
from repro.core.stencil import StencilSpec, WeightField

# Jacobi-form residuals are h^2-scaled; standard coarsening (mesh ratio 2,
# second-order operator) rescales the coarse right-hand side by ratio^2.
_RHS_SCALE = 4.0

# Damping for the "jacobi" smoother: undamped Jacobi does not damp the
# checkerboard mode at all (its S-eigenvalue is -1); omega = 0.8 is the
# classic smoothing-optimal choice for the 2D 5-point Laplacian.
_JACOBI_OMEGA = 0.8


# ---------------------------------------------------------------------------
# Transfer operators as StencilSpecs
# ---------------------------------------------------------------------------

def restriction_spec(ndim: int) -> StencilSpec:
    """Full-weighting restriction: w(off) = 2^-(ndim + |off|_1) on the 3^ndim
    box.  Apply on the fine grid, then sample every other point."""
    taps = {}
    for idx in np.ndindex(*(3,) * ndim):
        off = tuple(i - 1 for i in idx)
        taps[off] = 2.0 ** -(ndim + sum(abs(o) for o in off))
    return StencilSpec(taps=taps, name=f"restrict{ndim}d")


def prolongation_spec(ndim: int) -> StencilSpec:
    """Linear-interpolation prolongation: w(off) = 2^-|off|_1 on the 3^ndim
    box.  Zero-stuff the coarse values onto the even fine indices, then
    apply on the fine grid.  Equals ``2^ndim`` times the restriction
    stencil — the transpose pairing the property tests check."""
    taps = {}
    for idx in np.ndindex(*(3,) * ndim):
        off = tuple(i - 1 for i in idx)
        taps[off] = 2.0 ** -sum(abs(o) for o in off)
    return StencilSpec(taps=taps, name=f"prolong{ndim}d")


def coarse_shape(shape: tuple[int, ...]) -> tuple[int, ...]:
    """Shape of the next-coarser grid: the even-index points, (s+1)//2."""
    return tuple((s + 1) // 2 for s in shape)


def coarsen_spec(spec: StencilSpec) -> StencilSpec:
    """Re-discretize ``spec`` on the next-coarser grid.

    Constant-coefficient taps transfer unchanged (the Jacobi weights of a
    second-order operator are mesh-size free); per-cell weight fields are
    injected — sampled at the even fine indices the coarse points sit on.
    """
    if not spec.is_variable:
        return spec
    nd = spec.ndim
    sample = (slice(None, None, 2),) * nd
    taps = {}
    for off, w in spec.taps:
        if isinstance(w, WeightField):
            taps[off] = WeightField(w.array[sample])
        else:
            taps[off] = w
    return StencilSpec(taps=taps, name=f"{spec.name}_coarse")


# ---------------------------------------------------------------------------
# Red-black Gauss-Seidel
# ---------------------------------------------------------------------------

def _parity_mask(shape: tuple[int, ...]) -> np.ndarray:
    """True on the red points: (sum of indices) even."""
    grids = np.indices(shape).sum(axis=0)
    return (grids % 2) == 0


def red_black_step(
    u: jnp.ndarray,
    step,
    *,
    g: jnp.ndarray | None = None,
    mask: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """One red-black Gauss-Seidel sweep: two masked half-sweeps.

    ``step`` is any full-grid Jacobi update (e.g. a 1-iteration
    ``StencilPlan``); ``g`` an optional per-cell source added through
    ``mask`` (the interior mask) on coarse multigrid levels.  The red
    half-sweep commits the update on the even-parity points only, then the
    black half-sweep re-applies ``step`` to the half-updated field and
    commits the odd-parity points.  For star stencils red points read only
    black neighbours and vice versa, so this is exact Gauss-Seidel.
    """
    red = jnp.asarray(_parity_mask(u.shape))

    def half(v):
        y = step(v)
        if g is not None:
            y = y + (g if mask is None else mask * g)
        return y

    u = jnp.where(red, half(u), u)
    return jnp.where(red, u, half(u))


# ---------------------------------------------------------------------------
# The V-cycle engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MGResult:
    """Outcome of one :meth:`Multigrid.solve` call.

    Attributes:
      x: final field, shape ``grid_shape``.
      cycles: V-cycles executed.
      converged: whether ``||plan(x) - x|| <= atol + rtol*||plan(x)||`` was
        met before ``max_cycles`` (the same criterion ``core.solver`` uses,
        measured with the fine-level 1-iteration plan).
      residual: last measured residual (absolute update norm).
      residual_history: residual after each cycle, one entry per cycle.
      work_units: total fine-grid-equivalent stencil sweeps spent, the
        Jacobi-comparable cost (one plain Jacobi iteration = 1.0).
      work_per_cycle: work units one V-cycle costs (constant per hierarchy).
      level_shapes: grid shape of every level, finest first.
      backend: backend of the finest-level smoothing plan.
      wall_seconds: wall time of the solve call (includes compilation on the
        first call through a given Multigrid).
    """

    x: jnp.ndarray
    cycles: int
    converged: bool
    residual: float
    residual_history: np.ndarray
    work_units: float
    work_per_cycle: float
    level_shapes: tuple[tuple[int, ...], ...]
    backend: str
    wall_seconds: float


class Multigrid:
    """A prepared geometric-multigrid V-cycle solver for one (spec, grid).

    Construction builds the level hierarchy — smoothing plans, transfer
    plans, interior masks — through ``make_plan`` so every level rides the
    PR 1 dispatcher; the first :meth:`solve` call compiles the cycle.

    Arguments mirror :class:`core.solver.Solver` where they overlap:

      spec/grid_shape/bc: the fine-level problem, ``u = S(u)`` with a
        Dirichlet shell (scalar or ``DirichletBC``).
      smoother: ``"rb"`` (red-black Gauss-Seidel, default) or ``"jacobi"``
        (damped, omega=0.8) — undamped Jacobi is not a smoother.
      nu_pre/nu_post: smoothing sweeps before/after the coarse correction.
      min_size: stop coarsening once the next level would drop below this
        extent in any dimension; the coarsest level is solved by
        ``coarse_iters`` smoothing sweeps (cheap — the grid is tiny).
      backend: backend for every level's smoothing plan ("auto" prices each
        level separately).
      transfer_backend: backend for the restriction/prolongation plans;
        defaults to "reference" (raw bc=None application — on CPU the only
        non-interpret choice).
      rtol/atol/norm/max_cycles: convergence control, same criterion as the
        solver engine but checked once per V-cycle.  ``rtol=None,
        atol=None`` runs exactly ``max_cycles`` cycles.
    """

    def __init__(
        self,
        spec: StencilSpec,
        grid_shape: tuple[int, ...],
        *,
        bc: DirichletBC | float = 0.0,
        smoother: str = "rb",
        nu_pre: int = 2,
        nu_post: int = 2,
        min_size: int = 5,
        coarse_iters: int = 64,
        backend: str = "auto",
        transfer_backend: str = "reference",
        rtol: float | None = 1e-5,
        atol: float | None = 0.0,
        norm: str = "l2",
        max_cycles: int = 50,
        dtype=jnp.float32,
        interpret: bool | None = None,
        device_kind: str | None = None,
    ):
        if smoother not in ("rb", "jacobi"):
            raise ValueError(f"smoother must be 'rb' or 'jacobi', got "
                             f"{smoother!r}")
        if norm not in ("l2", "linf"):
            raise ValueError(f"norm must be 'l2' or 'linf', got {norm!r}")
        if min(grid_shape) < min_size:
            raise ValueError(
                f"grid {tuple(grid_shape)} is already below min_size="
                f"{min_size}; use core.solver.solve directly")
        if nu_pre < 0 or nu_post < 0 or nu_pre + nu_post == 0:
            raise ValueError("need at least one smoothing sweep per level")
        self.spec = spec
        self.grid_shape = tuple(grid_shape)
        self.bc = bc if isinstance(bc, DirichletBC) else DirichletBC(float(bc))
        self.smoother = smoother
        self.nu_pre, self.nu_post = int(nu_pre), int(nu_post)
        self.coarse_iters = int(coarse_iters)
        self.fixed = rtol is None and atol is None
        self.rtol = 0.0 if rtol is None else float(rtol)
        self.atol = 0.0 if atol is None else float(atol)
        if not self.fixed and self.rtol <= 0.0 and self.atol <= 0.0:
            raise ValueError(
                "unsatisfiable convergence criterion (rtol and atol both "
                "zero/None): set one > 0, or pass rtol=None, atol=None for "
                "fixed-cycle mode")
        self.norm = norm
        self.max_cycles = int(max_cycles)
        self.dtype = dtype

        # -- level hierarchy ------------------------------------------------
        shapes = [self.grid_shape]
        while min(coarse_shape(shapes[-1])) >= min_size:
            shapes.append(coarse_shape(shapes[-1]))
        self.level_shapes = tuple(shapes)
        nlev = len(shapes)

        specs = [spec]
        for _ in range(nlev - 1):
            specs.append(coarsen_spec(specs[-1]))

        plan_kw = dict(mode=BoundaryMode.MASK, iters=1, dtype=dtype,
                       interpret=interpret, device_kind=device_kind)
        # Smoothing plans: the fine level carries the real BC, coarse levels
        # solve the error equation with a zero shell.
        self.plans: list[StencilPlan] = [
            make_plan(specs[l], shapes[l], backend=backend,
                      bc=self.bc if l == 0 else 0.0, **plan_kw)
            for l in range(nlev)
        ]
        # Transfer plans live on the fine grid of each level pair, applied
        # raw (bc=None): zero-pad semantics make restriction/prolongation
        # exact adjoints (up to the 2^ndim stencil scale).
        nd = spec.ndim
        self._restrict_plans = [
            make_plan(restriction_spec(nd), shapes[l], backend=transfer_backend,
                      bc=None, **plan_kw)
            for l in range(nlev - 1)
        ]
        self._prolong_plans = [
            make_plan(prolongation_spec(nd), shapes[l],
                      backend=transfer_backend, bc=None, **plan_kw)
            for l in range(nlev - 1)
        ]
        self._masks = [DirichletBC(0.0).interior_mask(s, dtype) for s in shapes]
        self._reds = [jnp.asarray(_parity_mask(s)) for s in shapes]
        self.backend = self.plans[0].backend

        # -- work accounting (fine-grid sweep equivalents) -------------------
        n0 = float(np.prod(self.grid_shape))
        ratio = [float(np.prod(s)) / n0 for s in shapes]
        sweeps = 2.0 if smoother == "rb" else 1.0  # rb = two half-sweeps
        per_cycle = 0.0
        for l in range(nlev - 1):
            per_cycle += ((self.nu_pre + self.nu_post) * sweeps  # smoothing
                          + 1.0      # residual
                          + 2.0      # restriction + prolongation stencils
                          ) * ratio[l]
        per_cycle += self.coarse_iters * sweeps * ratio[-1]
        per_cycle += 1.0  # the per-cycle convergence-check application
        self.work_per_cycle = per_cycle

        self._cycle = jax.jit(self._build_cycle())
        self._check = jax.jit(self._build_check())

    # -- building blocks ----------------------------------------------------

    def _smooth(self, l: int, u: jnp.ndarray, g: jnp.ndarray | None):
        plan, mask, red = self.plans[l], self._masks[l], self._reds[l]

        def step(v):
            y = plan(v)
            if g is not None:
                y = y + mask * g
            return y

        if self.smoother == "jacobi":
            return (1.0 - _JACOBI_OMEGA) * u + _JACOBI_OMEGA * step(u)
        u = jnp.where(red, step(u), u)
        return jnp.where(red, u, step(u))

    def _residual(self, l: int, u: jnp.ndarray, g: jnp.ndarray | None):
        plan, mask = self.plans[l], self._masks[l]
        y = plan(u)
        if g is not None:
            y = y + mask * g
        return mask * (y - u)

    def _restrict(self, l: int, r: jnp.ndarray) -> jnp.ndarray:
        sample = (slice(None, None, 2),) * self.spec.ndim
        return self._restrict_plans[l](r)[sample]

    def _prolong(self, l: int, e: jnp.ndarray) -> jnp.ndarray:
        stuff = (slice(None, None, 2),) * self.spec.ndim
        full = jnp.zeros(self.level_shapes[l], e.dtype).at[stuff].set(e)
        return self._prolong_plans[l](full)

    def _build_cycle(self):
        nlev = len(self.level_shapes)

        def vcycle(l, u, g):
            for _ in range(self.nu_pre):
                u = self._smooth(l, u, g)
            if l == nlev - 1:
                for _ in range(self.coarse_iters - self.nu_pre):
                    u = self._smooth(l, u, g)
                return u
            r = self._residual(l, u, g)
            gc = self._masks[l + 1] * (_RHS_SCALE * self._restrict(l, r))
            ec = vcycle(l + 1,
                        jnp.zeros(self.level_shapes[l + 1], u.dtype), gc)
            u = u + self._masks[l] * self._prolong(l, ec)
            for _ in range(self.nu_post):
                u = self._smooth(l, u, g)
            return u

        return lambda u: vcycle(0, u, None)

    def _build_check(self):
        plan = self.plans[0]
        linf = self.norm == "linf"

        def gnorm(v):
            v = v.astype(jnp.float32)
            return jnp.max(jnp.abs(v)) if linf else jnp.sqrt(jnp.sum(v * v))

        def check(u):
            y = plan(u)
            return gnorm(y - u), gnorm(y)

        return check

    # -- public API ----------------------------------------------------------

    def solve(self, x0: jnp.ndarray) -> MGResult:
        """Run V-cycles from ``x0`` (bare grid, shape ``grid_shape``)."""
        x0 = jnp.asarray(x0, self.dtype)
        if x0.shape != self.grid_shape:
            raise ValueError(
                f"multigrid built for grid {self.grid_shape}, got "
                f"{x0.shape} (batched multigrid is not supported — "
                f"solve instances one at a time)")
        t0 = time.perf_counter()
        u = self.bc.set_boundary(x0)
        history: list[float] = []
        converged = False
        work = 0.0
        residual = float("inf")
        cycles = 0
        for _ in range(self.max_cycles):
            u = self._cycle(u)
            cycles += 1
            work += self.work_per_cycle
            err, ref = self._check(u)
            residual = float(err)
            history.append(residual)
            if not self.fixed and \
                    residual <= self.atol + self.rtol * float(ref):
                converged = True
                break
        jax.block_until_ready(u)
        wall = time.perf_counter() - t0
        return MGResult(
            x=u, cycles=cycles, converged=converged, residual=residual,
            residual_history=np.asarray(history, np.float32),
            work_units=work, work_per_cycle=self.work_per_cycle,
            level_shapes=self.level_shapes, backend=self.backend,
            wall_seconds=wall)

    __call__ = solve


def multigrid_solve(
    spec: StencilSpec,
    x0: jnp.ndarray,
    *,
    bc: DirichletBC | float = 0.0,
    **kwargs,
) -> MGResult:
    """One-shot multigrid solve of ``u = S(u)`` with a Dirichlet shell.

    ``x0`` is a bare grid; see :class:`Multigrid` for the knobs and
    :class:`MGResult` for what comes back.  Build a :class:`Multigrid`
    directly to amortize hierarchy construction over repeated solves.
    """
    x0 = jnp.asarray(x0)
    if x0.ndim != spec.ndim:
        raise ValueError(
            f"x0.ndim={x0.ndim} != spec.ndim={spec.ndim} (multigrid takes a "
            f"bare grid; batched solves are not supported)")
    mg = Multigrid(spec, tuple(x0.shape), bc=bc, **kwargs)
    return mg.solve(x0)
