"""Shared plan/solver cache — the serving tier's compile-once artifact store.

The expensive artifact in this repo is a compiled ``Solver`` loop: building a
plan and jitting the ``lax.while_loop`` for a fresh (spec, shape, backend)
costs seconds, while a warm converged Table-1 solve runs in tens of
milliseconds.  ``PlanCache`` amortizes that cost the way Cerebras' modelzoo
splits compile-once artifacts from streamed work: solves are admitted
through a bounded LRU cache keyed so that *near-miss* requests reuse an
already-compiled loop instead of recompiling.

Two entry kinds:

* **Bucketed** entries (the default for masked Dirichlet solves) are keyed
  by ``autotune``'s canonicalization — the tap-offset signature of the spec
  (not its weight values) and the power-of-two ``shape_bucket`` of the grid
  — and hold one Solver built on the *bucket* shape with every tap lifted to
  a runtime ``WeightField`` operand.  A request on any member shape executes
  by embedding its problem in the bucket grid ("pad-to-bucket"):

    - tap weights are streamed as the ``fields`` operand: the request's
      weights at original-interior cells, zero everywhere else;
    - original-*shell* cells that are not on the padded outer ring have zero
      weights, so pinning them to the Dirichlet value rides the ``source``
      operand; shell cells that do land on the ring ride the ``bc_value``
      grid operand;
    - padding ("junk") cells have zero weights, zero source, zero init —
      they stay exactly 0.0 through every iteration, read as the same zeros
      an unpadded plan's zero-filled boundary reads would produce, and
      contribute exact zeros to both residual norms.

  The padded solve therefore reproduces the unpadded solve exactly — field,
  iteration counts, convergence decisions, residual history — for any tap
  radius.  (Two caveats: the cached path seeds ``x0``'s shell with the
  boundary value before the loop, exactly as every plan does internally, so
  the *first-chunk* residual ignores whatever the caller left on the shell —
  iterates never depend on those values either way.  And while
  constant-weight solves come back bit-for-bit, XLA may contract the
  per-cell multiply-adds of *variable-coefficient* taps differently for the
  bucket-shaped kernel, so those fields can drift by an ulp; iteration
  counts and convergence decisions still match.)

  Scalar-weight variations of one operator family share a single compiled
  loop, as do all shapes in a bucket and all Dirichlet values.  The backend
  for a bucket entry is chosen by a short *measured probe* over the
  operand-capable backends (the analytic roofline misprices the gather paths
  badly on CPU); the probe consults the shared tuned table's schedule for
  the family/bucket cell but never writes to it.

* **Exact** entries fall back to a Solver keyed by the full request (spec,
  exact shape, backend, bc, mode, ...) when the request cannot ride the
  embedding: MATRIX mode (dense), ``bc=None`` raw application, array-valued
  static BCs, Pallas backends (no source operand), meshes, or a pad ratio
  above ``max_pad_ratio`` (an oversized entry would waste more compute
  padding than it saves compiling).  Multigrid hierarchies cache the same
  way via :meth:`PlanCache.multigrid`.

Stats (hits / misses / evictions / rebuilds / compile-seconds) are surfaced
on the cache object; corrupt entries are evicted and rebuilt once.  The
module-level :func:`default_plan_cache` is the process-wide instance that
``core.adjoint`` and ``serve.engine`` share.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.boundary import BoundaryMode, DirichletBC
from repro.core.stencil import StencilSpec, WeightField

# Backends whose plans take the full runtime-operand signature the embedding
# streams (fields + source + bc_value), per spec rank.  Dense is excluded
# (MATRIX-mode semantics), the Pallas paths bake the BC and take no source.
_PAD_BACKENDS = {
    1: ("reference",),
    2: ("reference", "conv"),
    3: ("reference", "conv3d_native"),
}


@dataclasses.dataclass
class CacheStats:
    """Counters surfaced on a :class:`PlanCache`."""

    hits: int = 0
    misses: int = 0
    evictions: int = 0
    rebuilds: int = 0
    compile_seconds: float = 0.0
    probe_seconds: float = 0.0

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def as_dict(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "rebuilds": self.rebuilds,
                "compile_seconds": self.compile_seconds,
                "probe_seconds": self.probe_seconds,
                "hit_rate": self.hit_rate}


@dataclasses.dataclass
class _Entry:
    kind: str              # "bucket" | "exact" | "multigrid"
    key: tuple
    obj: object            # Solver or Multigrid
    backend: str
    bucket: tuple | None
    compile_seconds: float


def _bc_key(bc):
    """Hashable identity of a static BC (scalar, array, DirichletBC, None)."""
    if bc is None:
        return None
    if isinstance(bc, DirichletBC):
        bc = bc.value
    if isinstance(bc, (int, float)):
        return ("s", float(bc))
    arr = np.asarray(bc)
    return ("a", arr.shape, arr.tobytes())


def _bc_scalar(bc) -> float | None:
    """The scalar Dirichlet value, or None if bc is not a plain scalar."""
    if isinstance(bc, DirichletBC):
        bc = bc.value
    if isinstance(bc, (int, float)):
        return float(bc)
    return None


class CachedSolver:
    """Handle to one cached Solver, adapted to the caller's request.

    ``solve``/``run`` mirror :class:`core.solver.Solver` — ``run`` is the
    trace-safe core the adjoint machinery calls.  For a bucketed entry both
    embed the request in the bucket grid (module docstring) and slice the
    result back to the original shape; for an exact entry they delegate
    directly.  A call that blows up inside the cached object evicts and
    rebuilds the entry once before re-raising.
    """

    def __init__(self, cache: "PlanCache", entry: _Entry, builder,
                 spec: StencilSpec, grid_shape: tuple[int, ...], dtype,
                 bc_scalar: float | None):
        self._cache = cache
        self._entry = entry
        self._builder = builder
        self.spec = spec
        self.grid_shape = tuple(grid_shape)
        self.dtype = dtype
        self.padded = entry.kind == "bucket"
        self.bucket = entry.bucket
        self.backend = entry.backend
        self._static_bc = bc_scalar
        if self.padded:
            self._prepare_embedding()

    # -- embedding constants (numpy once, jnp constants thereafter) --------

    def _prepare_embedding(self):
        nd = self.spec.ndim
        orig, bucket = self.grid_shape, self.bucket
        self._embed = tuple(slice(0, n) for n in orig)

        mask_o = np.zeros(orig, np.float32)
        mask_o[tuple(slice(1, -1) for _ in orig)] = 1.0
        shell_o = 1.0 - mask_o
        ring_p = np.ones(bucket, np.float32)
        ring_p[tuple(slice(1, -1) for _ in bucket)] = 0.0
        shell_embed = np.zeros(bucket, np.float32)
        shell_embed[self._embed] = shell_o

        # Template tap order == the request spec's canonical tap order (both
        # are sorted by offset), so row k of the fields operand is tap k.
        base = np.zeros((len(self.spec.taps),) + bucket, np.float32)
        var_idx = []
        for k, (off, w) in enumerate(self.spec.taps):
            if isinstance(w, WeightField):
                var_idx.append(k)
                w_o = np.asarray(w.values, np.float32)
            else:
                w_o = np.full(orig, float(w), np.float32)
            base[k][self._embed] = w_o * mask_o
        self._var_idx = tuple(var_idx)

        self._mask_o = mask_o
        self._shell_o = shell_o
        self._pin_nonring = shell_embed * (1.0 - ring_p)
        self._pin_ring = shell_embed * ring_p
        self._base_fields = base

    def _padded_operands(self, x0, fields, source, bc_value):
        """(x0p, fields, source, bc_value) on the bucket grid.

        Concrete operands embed in plain numpy (no per-shape XLA op
        compiles on the serving hot path); traced operands (the adjoint
        machinery under jit/grad) take the equivalent jnp path.
        """
        from jax.core import Tracer
        if any(isinstance(v, Tracer)
               for v in (x0, fields, source, bc_value) if v is not None):
            return self._traced_operands(x0, fields, source, bc_value)

        nd = self.spec.ndim
        dt = np.dtype(jnp.dtype(self.dtype))
        x0 = np.asarray(x0, dt)
        squeeze = x0.ndim == nd
        if squeeze:
            x0 = x0[None]
        if x0.shape[1:] != self.grid_shape:
            raise ValueError(
                f"cached solver built for grid {self.grid_shape}, got "
                f"{x0.shape[1:]}")
        b = x0.shape[0]

        v = np.asarray(self._static_bc if bc_value is None else bc_value, dt)
        if v.ndim not in (0, nd):
            raise ValueError(
                f"bc_value must be a scalar or a {nd}D grid, got shape "
                f"{v.shape}")
        pinned = np.broadcast_to(v, self.grid_shape) * self._shell_o
        pin_embed = np.zeros(self.bucket, dt)
        pin_embed[self._embed] = pinned

        x0p = np.zeros((b,) + self.bucket, dt)
        x0p[(slice(None),) + self._embed] = x0 * self._mask_o + pinned

        F = self._base_fields.astype(dt, copy=False)
        if fields is not None:
            fields = np.asarray(fields, dt)
            self._check_fields(fields.shape)
            F = F.copy()
            for row, k in enumerate(self._var_idx):
                F[(k,) + self._embed] = fields[row] * self._mask_o

        src_p = pin_embed * self._pin_nonring
        if source is not None:
            s = np.asarray(source, dt)
            if s.ndim == nd:
                sp = np.zeros(self.bucket, dt)
                sp[self._embed] = s * self._mask_o
            elif s.ndim == nd + 1:
                sp = np.zeros((s.shape[0],) + self.bucket, dt)
                sp[(slice(None),) + self._embed] = s * self._mask_o
            else:
                raise ValueError(
                    f"source must be (*grid) or (batch, *grid), got shape "
                    f"{s.shape}")
            src_p = sp + src_p

        return x0p, F, src_p, pin_embed * self._pin_ring, squeeze

    def _check_fields(self, shape):
        want = (len(self._var_idx), *self.grid_shape)
        if tuple(shape) != want:
            raise ValueError(
                f"fields operand must be shaped {want}, got {tuple(shape)}")

    def _traced_operands(self, x0, fields, source, bc_value):
        nd = self.spec.ndim
        dt = self.dtype
        x0 = jnp.asarray(x0, dt)
        squeeze = x0.ndim == nd
        if squeeze:
            x0 = x0[None]
        if x0.shape[1:] != self.grid_shape:
            raise ValueError(
                f"cached solver built for grid {self.grid_shape}, got "
                f"{x0.shape[1:]}")
        b = x0.shape[0]
        mask_o = jnp.asarray(self._mask_o, dt)
        shell_o = jnp.asarray(self._shell_o, dt)

        v = jnp.asarray(self._static_bc if bc_value is None else bc_value, dt)
        if v.ndim not in (0, nd):
            raise ValueError(
                f"bc_value must be a scalar or a {nd}D grid, got shape "
                f"{v.shape}")
        pinned = jnp.broadcast_to(v, self.grid_shape) * shell_o
        pin_embed = jnp.zeros(self.bucket, dt).at[self._embed].set(pinned)

        batch_embed = (slice(None),) + self._embed
        x0p = jnp.zeros((b,) + self.bucket, dt) \
            .at[batch_embed].set(x0 * mask_o + pinned)

        F = jnp.asarray(self._base_fields, dt)
        if fields is not None:
            fields = jnp.asarray(fields, dt)
            self._check_fields(fields.shape)
            rows = jnp.zeros((len(self._var_idx),) + self.bucket, dt) \
                .at[batch_embed].set(fields * mask_o)
            F = F.at[jnp.asarray(self._var_idx)].set(rows)

        src_p = pin_embed * jnp.asarray(self._pin_nonring, dt)
        if source is not None:
            s = jnp.asarray(source, dt)
            if s.ndim == nd:
                sp = jnp.zeros(self.bucket, dt) \
                    .at[self._embed].set(s * mask_o)
            elif s.ndim == nd + 1:
                sp = jnp.zeros((s.shape[0],) + self.bucket, dt) \
                    .at[batch_embed].set(s * mask_o)
            else:
                raise ValueError(
                    f"source must be (*grid) or (batch, *grid), got shape "
                    f"{s.shape}")
            src_p = sp + src_p

        return x0p, F, src_p, pin_embed * jnp.asarray(self._pin_ring, dt), \
            squeeze

    # -- degradation: evict + rebuild a corrupt entry once -----------------

    def _attempt(self, fn):
        try:
            return fn(self._entry.obj)
        except Exception:
            self._entry = self._cache._replace(self._entry.key, self._builder)
            self.backend = self._entry.backend
            return fn(self._entry.obj)

    # -- public API --------------------------------------------------------

    def run(self, x0, *, fields=None, source=None, bc_value=None):
        """Trace-safe solve: ``(x, iterations, converged, residual)``."""
        if not self.padded:
            return self._attempt(lambda s: s.run(
                x0, fields=fields, source=source, bc_value=bc_value))
        x0p, F, src, bcg, squeeze = self._padded_operands(
            x0, fields, source, bc_value)
        x, iters, conv, res = self._attempt(lambda s: s.run(
            x0p, fields=F, source=src, bc_value=bcg))
        x = x[(slice(None),) + self._embed]
        if squeeze:
            return x[0], iters[0], conv[0], res[0]
        return x, iters, conv, res

    def solve(self, x0, *, fields=None, source=None, bc_value=None):
        """Run the cached time loop; returns a ``SolveResult``."""
        if not self.padded:
            return self._attempt(lambda s: s.solve(
                x0, fields=fields, source=source, bc_value=bc_value))
        x0p, F, src, bcg, squeeze = self._padded_operands(
            x0, fields, source, bc_value)
        res = self._attempt(lambda s: s.solve(
            x0p, fields=F, source=src, bc_value=bcg))
        # Unpad in numpy: an eager lax slice would compile once per
        # original shape, which is exactly what the bucket exists to avoid.
        x = jnp.asarray(np.asarray(res.x)[(slice(None),) + self._embed])
        if squeeze:
            return dataclasses.replace(
                res, x=x[0], iterations=int(res.iterations[0]),
                converged=bool(res.converged[0]),
                residual=float(res.residual[0]),
                residual_history=res.residual_history[:, 0])
        return dataclasses.replace(res, x=x)

    __call__ = solve


class PlanCache:
    """Bounded LRU cache of compiled Solver / Multigrid artifacts.

    Args:
      capacity: max cached entries; the least-recently-used is evicted.
      max_pad_ratio: bucketed requests whose bucket volume exceeds this
        multiple of the request volume degrade to an exact entry.
      probe: measure the operand-capable backends per bucket cell (a few
        short timed plan calls, once per cell) instead of trusting the
        analytic roofline.  Probe time counts toward ``compile_seconds``.
      probe_iters: iterations per probe measurement.
      tuned: tuned-table handle forwarded to Solver construction ("default"
        = the committed TUNED_stencil.json); bucket-cell probes consult it
        for candidate schedules but never write to it.
    """

    def __init__(self, capacity: int = 32, *, max_pad_ratio: float = 4.0,
                 probe: bool = True, probe_iters: int = 8, tuned="default"):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self.max_pad_ratio = float(max_pad_ratio)
        self.probe = bool(probe)
        self.probe_iters = int(probe_iters)
        self.tuned = tuned
        self.stats = CacheStats()
        self._entries: OrderedDict[tuple, _Entry] = OrderedDict()
        self._building: dict[tuple, threading.Event] = {}
        self._probe_winners: dict[tuple, str] = {}
        self._lock = threading.RLock()

    # -- bookkeeping -------------------------------------------------------

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()

    def keys(self) -> list[tuple]:
        with self._lock:
            return list(self._entries)

    def _acquire(self, key: tuple, build) -> _Entry:
        """Entry for ``key``, building under a per-key latch on miss."""
        for _ in range(2):
            with self._lock:
                ent = self._entries.get(key)
                if ent is not None:
                    self._entries.move_to_end(key)
                    self.stats.hits += 1
                    return ent
                self.stats.misses += 1
                latch = self._building.get(key)
                if latch is None:
                    latch = threading.Event()
                    self._building[key] = latch
                    building = True
                else:
                    building = False
            if not building:
                latch.wait(timeout=600.0)
                with self._lock:
                    ent = self._entries.get(key)
                    if ent is not None:
                        self._entries.move_to_end(key)
                        return ent
                continue  # builder failed; retry (possibly becoming builder)
            try:
                ent = build()
            finally:
                with self._lock:
                    self._building.pop(key, None)
                latch.set()
            self._insert(ent)
            return ent
        raise RuntimeError(f"plan-cache build for {key!r} failed repeatedly")

    def _insert(self, ent: _Entry) -> None:
        with self._lock:
            self._entries[ent.key] = ent
            self._entries.move_to_end(ent.key)
            self.stats.compile_seconds += ent.compile_seconds
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self.stats.evictions += 1

    def _replace(self, key: tuple, build) -> _Entry:
        """Evict ``key`` and rebuild it (corrupt-entry degradation)."""
        with self._lock:
            self._entries.pop(key, None)
            self.stats.rebuilds += 1
        ent = build()
        self._insert(ent)
        return ent

    # -- backend choice for bucket cells -----------------------------------

    def _template(self, offsets, bucket) -> StencilSpec:
        taps = {off: WeightField(np.zeros(bucket, np.float32))
                for off in offsets}
        return StencilSpec(taps=taps, name=f"cache_template_{len(offsets)}t")

    def _bucket_backend(self, template: StencilSpec, bucket, dtype,
                        interpret, device_kind) -> str:
        from repro.core import autotune
        from repro.core.plan import (DEVICE_PROFILES, backend_support,
                                     estimate_seconds, make_plan)
        nd = template.ndim
        cands = [b for b in _PAD_BACKENDS.get(nd, ("reference",))
                 if backend_support(b, template, grid_shape=bucket,
                                    mode=BoundaryMode.MASK,
                                    bc=DirichletBC(0.0))]
        if not cands:
            return "reference"
        if len(cands) == 1:
            return cands[0]
        offsets = tuple(off for off, _ in template.taps)
        memo_key = (offsets, tuple(bucket), autotune.dtype_key(dtype),
                    interpret, device_kind)
        with self._lock:
            if memo_key in self._probe_winners:
                return self._probe_winners[memo_key]

        if not self.probe:
            table = autotune.resolve_table(self.tuned)
            if table is not None and len(table):
                entry = table.lookup(
                    device_kind or jax.default_backend(),
                    autotune.spec_family(template), tuple(bucket),
                    autotune.dtype_key(dtype))
                if entry is not None and entry.backend in cands:
                    return entry.backend
            device = DEVICE_PROFILES.get(
                device_kind or jax.default_backend(), DEVICE_PROFILES["cpu"])
            return min(cands, key=lambda b: estimate_seconds(
                b, template, tuple(bucket), 100, device))

        # Measured probe: a short var-operand plan per candidate, timed
        # after one warmup (the warmup absorbs compilation).
        t_probe = time.perf_counter()
        fields = jnp.asarray(template.field_stack(), dtype)
        x = jnp.zeros((1,) + tuple(bucket), dtype)
        src = jnp.zeros(tuple(bucket), dtype)
        bcg = jnp.zeros(tuple(bucket), dtype)
        best, best_t = cands[0], float("inf")
        for cand in cands:
            try:
                plan = make_plan(template, tuple(bucket), backend=cand,
                                 bc=DirichletBC(0.0), mode=BoundaryMode.MASK,
                                 iters=self.probe_iters, dtype=dtype,
                                 interpret=interpret,
                                 device_kind=device_kind, tuned=None)
                jax.block_until_ready(
                    plan(x, fields=fields, source=src, bc_value=bcg))
                t0 = time.perf_counter()
                jax.block_until_ready(
                    plan(x, fields=fields, source=src, bc_value=bcg))
                dt_c = time.perf_counter() - t0
            except Exception:
                continue
            if dt_c < best_t:
                best, best_t = cand, dt_c
        with self._lock:
            self.stats.probe_seconds += time.perf_counter() - t_probe
            self._probe_winners[memo_key] = best
        return best

    # -- entry builders ----------------------------------------------------

    def _build_bucket(self, key, offsets, bucket, dtype, cfg) -> _Entry:
        from repro.core.solver import Solver
        (rtol, atol, norm, check_every, max_iters, interpret,
         device_kind) = cfg
        t0 = time.perf_counter()
        template = self._template(offsets, bucket)
        backend = self._bucket_backend(template, bucket, dtype, interpret,
                                       device_kind)
        solver = Solver(
            template, bucket, backend=backend, bc=DirichletBC(0.0),
            mode=BoundaryMode.MASK, rtol=rtol, atol=atol, norm=norm,
            check_every=check_every, max_iters=max_iters, dtype=dtype,
            interpret=interpret, device_kind=device_kind, tuned=self.tuned)
        return _Entry(kind="bucket", key=key, obj=solver, backend=backend,
                      bucket=tuple(bucket),
                      compile_seconds=time.perf_counter() - t0)

    def _build_exact(self, key, spec, grid_shape, dtype, backend, bc, mode,
                     cfg, fuse) -> _Entry:
        from repro.core.solver import Solver
        (rtol, atol, norm, check_every, max_iters, interpret,
         device_kind) = cfg
        t0 = time.perf_counter()
        solver = Solver(
            spec, grid_shape, backend=backend, bc=bc, mode=mode, rtol=rtol,
            atol=atol, norm=norm, check_every=check_every,
            max_iters=max_iters, fuse=fuse, dtype=dtype, interpret=interpret,
            device_kind=device_kind, tuned=self.tuned)
        return _Entry(kind="exact", key=key, obj=solver,
                      backend=solver.backend, bucket=None,
                      compile_seconds=time.perf_counter() - t0)

    # -- public API --------------------------------------------------------

    def solver(
        self,
        spec: StencilSpec,
        grid_shape: tuple[int, ...],
        *,
        dtype=jnp.float32,
        backend: str = "auto",
        bc: DirichletBC | float | None = 0.0,
        mode: BoundaryMode = BoundaryMode.MASK,
        rtol: float | None = 1e-5,
        atol: float | None = 0.0,
        norm: str = "l2",
        check_every: int | None = None,
        max_iters: int = 10_000,
        fuse: int | None = None,
        interpret: bool | None = None,
        device_kind: str | None = None,
    ) -> CachedSolver:
        """A :class:`CachedSolver` for this request (compiling on miss).

        Masked scalar-Dirichlet requests on an operand-capable backend ride
        a bucketed entry (module docstring): every shape in the power-of-two
        bucket, every scalar-weight variation of the tap-offset family, and
        every Dirichlet value share one compiled loop.  Everything else —
        and bucketed requests whose padding overhead exceeds
        ``max_pad_ratio`` — gets an exact entry keyed by the full request.
        """
        from repro.core import autotune
        grid_shape = tuple(int(n) for n in grid_shape)
        if spec.ndim != len(grid_shape):
            raise ValueError(
                f"spec is {spec.ndim}D but grid is {len(grid_shape)}D")
        cfg = (rtol, atol, norm, check_every, max_iters, interpret,
               device_kind)
        dkey = autotune.dtype_key(dtype)
        bc_scalar = _bc_scalar(bc)

        bucket = autotune.shape_bucket(grid_shape)
        pad_ratio = float(np.prod(bucket)) / max(float(np.prod(grid_shape)), 1)
        bucketable = (
            mode is BoundaryMode.MASK
            and bc is not None and bc_scalar is not None
            and (backend == "auto"
                 or backend in _PAD_BACKENDS.get(spec.ndim, ()))
            and pad_ratio <= self.max_pad_ratio
        )

        if bucketable:
            offsets = tuple(off for off, _ in spec.taps)
            key = ("bucket", offsets, bucket, dkey, backend, cfg)
            builder = lambda: self._build_bucket(  # noqa: E731
                key, offsets, bucket, dtype, cfg)
        else:
            key = ("exact", spec, grid_shape, dkey, backend, _bc_key(bc),
                   mode, cfg, fuse)
            builder = lambda: self._build_exact(  # noqa: E731
                key, spec, grid_shape, dtype, backend, bc, mode, cfg, fuse)
        entry = self._acquire(key, builder)
        return CachedSolver(self, entry, builder, spec, grid_shape, dtype,
                            bc_scalar)

    def solve(self, spec: StencilSpec, x0, **kwargs):
        """One-shot cached solve — ``core.solver.solve`` through the cache.

        Solve-time operands (``fields``/``source``/``bc_value``) pass
        through; everything else configures :meth:`solver`.
        """
        operands = {k: kwargs.pop(k, None)
                    for k in ("fields", "source", "bc_value")}
        x0 = jnp.asarray(x0)
        if x0.ndim not in (spec.ndim, spec.ndim + 1):
            raise ValueError(
                f"x0.ndim={x0.ndim} incompatible with a {spec.ndim}D spec "
                f"(expect grid or batch+grid)")
        grid_shape = tuple(x0.shape[-spec.ndim:])
        if "dtype" not in kwargs and jnp.issubdtype(x0.dtype, jnp.floating):
            kwargs["dtype"] = x0.dtype
        return self.solver(spec, grid_shape, **kwargs).solve(x0, **operands)

    def multigrid(self, spec: StencilSpec, grid_shape: tuple[int, ...],
                  **kwargs):
        """A cached :class:`core.multigrid.Multigrid` hierarchy.

        Exact-keyed (hierarchies bake their level shapes); shares the LRU
        store and stats with the solver entries.
        """
        from repro.core.multigrid import Multigrid
        grid_shape = tuple(int(n) for n in grid_shape)
        bc = kwargs.get("bc", 0.0)
        key = ("multigrid", spec, grid_shape, _bc_key(bc),
               tuple(sorted((k, v) for k, v in kwargs.items() if k != "bc")))

        def builder():
            t0 = time.perf_counter()
            mg = Multigrid(spec, grid_shape, **kwargs)
            return _Entry(kind="multigrid", key=key, obj=mg,
                          backend=kwargs.get("backend", "auto"), bucket=None,
                          compile_seconds=time.perf_counter() - t0)

        return self._acquire(key, builder).obj


# ---------------------------------------------------------------------------
# Process-wide default instance (shared by core.adjoint and serve.engine)
# ---------------------------------------------------------------------------

_default_cache: PlanCache | None = None
_default_lock = threading.Lock()


def default_plan_cache() -> PlanCache:
    """The process-wide shared cache (created on first use, capacity 64)."""
    global _default_cache
    with _default_lock:
        if _default_cache is None:
            _default_cache = PlanCache(capacity=64)
        return _default_cache


def set_default_plan_cache(cache: PlanCache | None) -> PlanCache | None:
    """Swap the process-wide cache (pass None to reset); returns the old one."""
    global _default_cache
    with _default_lock:
        old, _default_cache = _default_cache, cache
        return old
