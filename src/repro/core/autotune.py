"""Measured autotuner for the stencil hot path — schedules priced by clock,
not by roofline.

``choose_backend`` (core/plan.py) prices every backend from an analytic
roofline; that model cannot see interpret-mode Pallas overheads, cache
effects, or the real crossover between temporal-fusion rim recompute and HBM
savings.  This module closes the loop the way the WSE scaling papers do
(schedule *search*, then persist the winner): it lowers candidate schedules —
backend × temporal fuse depth × block shape × rim strategy — through
``make_plan``, measures each one, and records the results in a versioned
table keyed by ``(device_kind, spec family, shape bucket, dtype)``.

The committed artifact (``TUNED_stencil.json`` at the repo root) is the
plan-once/solve-many analogue of Cerebras' compile-once artifact split:
dispatch (``choose_backend``/``make_plan``/``select_fuse``) consults the
table *before* the roofline, with nearest-shape-bucket matching and an
explicit roofline fallback when no entry applies.  Interpret-mode Pallas
measurements are recorded for the trajectory but structurally tagged
(``interpreted: true``) and never allowed to win a cell — the mispricing
family this PR fixes.

Regenerate the table with ``python -m benchmarks.autotune_bench`` and
validate it with ``python -m repro.core.autotune --check TUNED_stencil.json``
(what ``scripts/ci.sh --tune-check`` runs).
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.boundary import BoundaryMode, DirichletBC
from repro.core.stencil import StencilSpec, WeightField, star

SCHEMA_VERSION = 1
DEFAULT_TABLE_NAME = "TUNED_stencil.json"

# Schedule-search space for the 2D Pallas paths.  Interpreted candidates are
# measured once (fuse=1, default block) purely for the record — they can
# never win, so sweeping their schedule space would waste tuner time.
FUSE_CANDIDATES = (1, 2, 4, 8, 16)
RESIDENT_FUSE_CANDIDATES = (16, 32, 64)
BLOCK_H_CANDIDATES = (64, 128, 256)
# Deep-halo fuse depths swept per mesh shape (clamped to the local tile).
HALO_FUSE_CANDIDATES = (1, 2, 4, 8)


class TableError(ValueError):
    """A tuned table failed schema validation."""


# ---------------------------------------------------------------------------
# Cell keys: family + shape bucket
# ---------------------------------------------------------------------------

def spec_family(spec: StencilSpec) -> str:
    """Structural family key of a spec: what tuned timings transfer across.

    Performance of a schedule depends on the tap geometry (ndim, radius,
    tap count) and whether taps carry per-cell weight fields — not on the
    scalar weight values — so two Laplace-like specs with different
    coefficients share a family (and a tuned schedule).
    """
    fam = f"{spec.ndim}d/r{spec.radius}/t{len(spec.taps)}"
    if spec.is_variable:
        fam += "/var"
    return fam


def shape_bucket(grid_shape: tuple[int, ...]) -> tuple[int, ...]:
    """Round every extent up to a power of two — the bucket key."""
    return tuple(1 if d <= 1 else 1 << (int(d) - 1).bit_length()
                 for d in grid_shape)


def bucket_distance(a: tuple[int, ...], b: tuple[int, ...]) -> float:
    """Sum of |log2| extent ratios; inf across ranks (no transfer)."""
    if len(a) != len(b):
        return math.inf
    return float(sum(abs(math.log2(x / y)) for x, y in zip(a, b)))


def family_representative(family: str,
                          bucket: tuple[int, ...]) -> StencilSpec:
    """A canonical spec for a family string, for legality checks.

    ``backend_support`` depends only on ndim / radius / variability (never on
    tap values), so a star stencil of the right rank and radius answers "is
    this backend legal for this cell" for every member of the family.
    """
    parts = family.split("/")
    try:
        nd = int(parts[0].rstrip("d"))
        radius = int(parts[1].lstrip("r"))
    except (IndexError, ValueError) as e:
        raise TableError(f"malformed family key {family!r}") from e
    spec = star(nd, [1.0 / (2 * nd * radius)] * radius)
    if "var" in parts[2:]:
        off, w = spec.taps[0]
        taps = dict(spec.taps)
        taps[off] = WeightField(np.full(bucket, float(w), np.float32))
        spec = StencilSpec(taps=taps, name=f"{spec.name}_var")
    return spec


# ---------------------------------------------------------------------------
# Entries and the table
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TunedEntry:
    """One measured schedule for one (device, family, bucket, dtype) cell."""

    device_kind: str
    family: str
    bucket: tuple[int, ...]
    dtype: str
    backend: str
    us_per_iter: float
    fuse: int = 1
    block_h: int | None = None
    rim: str | None = None
    interpreted: bool = False
    iters: int = 1          # iterations per timed call during measurement
    # Device-mesh tiling (n_row, n_col) a halo schedule was measured on —
    # halo timings do not transfer across mesh shapes, so lookups filter on
    # it.  None for every single-device backend (backward compatible with
    # pre-mesh tables).
    mesh: tuple[int, int] | None = None

    @property
    def cell(self) -> tuple:
        return (self.device_kind, self.family, self.bucket, self.dtype)

    def seconds(self, iters: int) -> float:
        return self.us_per_iter * 1e-6 * iters

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["bucket"] = list(self.bucket)
        if self.mesh is not None:
            d["mesh"] = list(self.mesh)
        else:
            del d["mesh"]
        return d

    @classmethod
    def from_json(cls, d: dict) -> "TunedEntry":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise TableError(f"unknown entry fields {sorted(unknown)}")
        missing = {"device_kind", "family", "bucket", "dtype", "backend",
                   "us_per_iter"} - set(d)
        if missing:
            raise TableError(f"entry missing fields {sorted(missing)}")
        d = dict(d)
        d["bucket"] = tuple(int(v) for v in d["bucket"])
        if d.get("mesh") is not None:
            d["mesh"] = tuple(int(v) for v in d["mesh"])
        return cls(**d)


class TunedTable:
    """A set of measured schedules with nearest-bucket lookup.

    Lookup semantics (the contract dispatch relies on):

      * entries group into cells by (device_kind, family, bucket, dtype);
      * ``lookup_cell`` bucketizes the query shape and returns the entries of
        the nearest recorded bucket within ``max_distance`` (sum of per-dim
        |log2| ratios — the default 1.0/dim tolerates one power of two of
        extrapolation per axis on average);
      * interpreted entries never win: ``lookup`` returns the fastest
        *non-interpreted* entry, or None (→ roofline fallback).
    """

    def __init__(self, entries: tuple[TunedEntry, ...] = (), source=None):
        self.entries: list[TunedEntry] = list(entries)
        self.source = source

    def __len__(self) -> int:
        return len(self.entries)

    def add(self, entry: TunedEntry) -> None:
        """Insert, replacing any entry with the same cell + schedule key."""
        key = (entry.cell, entry.backend, entry.fuse, entry.block_h,
               entry.rim, entry.mesh)
        self.entries = [
            e for e in self.entries
            if (e.cell, e.backend, e.fuse, e.block_h, e.rim, e.mesh) != key
        ]
        self.entries.append(entry)

    # -- lookup ------------------------------------------------------------

    def lookup_cell(
        self,
        device_kind: str,
        family: str,
        grid_shape: tuple[int, ...],
        dtype: str,
        *,
        max_distance: float | None = None,
        mesh_shape: tuple[int, int] | None = None,
    ) -> list[TunedEntry]:
        """Entries of the nearest recorded bucket; [] if none is close.

        ``mesh_shape`` is the (n_row, n_col) device tiling the caller will
        run on: mesh-keyed (halo) entries only apply when it matches, while
        mesh-less entries (every single-device schedule) always do.
        """
        want = shape_bucket(tuple(grid_shape))
        if max_distance is None:
            max_distance = float(len(want))
        near = [e for e in self.entries
                if e.device_kind == device_kind and e.family == family
                and e.dtype == dtype
                and (e.mesh is None
                     or (mesh_shape is not None
                         and tuple(e.mesh) == tuple(mesh_shape)))]
        if not near:
            return []
        best = min({e.bucket for e in near},
                   key=lambda b: bucket_distance(b, want))
        if bucket_distance(best, want) > max_distance:
            return []
        return [e for e in near if e.bucket == best]

    def lookup(
        self,
        device_kind: str,
        family: str,
        grid_shape: tuple[int, ...],
        dtype: str,
        *,
        max_distance: float | None = None,
        mesh_shape: tuple[int, int] | None = None,
    ) -> TunedEntry | None:
        """The fastest non-interpreted schedule for the cell, or None."""
        cell = self.lookup_cell(device_kind, family, grid_shape, dtype,
                                max_distance=max_distance,
                                mesh_shape=mesh_shape)
        live = [e for e in cell if not e.interpreted]
        if not live:
            return None
        return min(live, key=lambda e: e.us_per_iter)

    # -- persistence -------------------------------------------------------

    def to_json(self) -> dict:
        return {
            "schema": SCHEMA_VERSION,
            "entries": [e.to_json() for e in sorted(
                self.entries, key=lambda e: (e.cell, e.backend, e.fuse,
                                             e.block_h or 0, e.rim or ""))],
        }

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)
            f.write("\n")

    @classmethod
    def parse(cls, data: dict, source=None) -> "TunedTable":
        """Strict parse — raises :class:`TableError` on any schema problem."""
        if not isinstance(data, dict):
            raise TableError(f"tuned table must be a JSON object, "
                             f"got {type(data).__name__}")
        if data.get("schema") != SCHEMA_VERSION:
            raise TableError(
                f"tuned table schema {data.get('schema')!r} != supported "
                f"{SCHEMA_VERSION} (stale or future table)")
        entries = data.get("entries")
        if not isinstance(entries, list):
            raise TableError("tuned table lacks an 'entries' list")
        return cls(tuple(TunedEntry.from_json(e) for e in entries),
                   source=source)

    @classmethod
    def load(cls, path: str) -> "TunedTable":
        """Forgiving load: a corrupt/stale/missing table degrades to an
        empty one with a warning — dispatch falls back to the roofline and
        never crashes on a bad artifact."""
        if not os.path.exists(path):
            return cls(source=path)
        try:
            with open(path) as f:
                data = json.load(f)
            return cls.parse(data, source=path)
        except (json.JSONDecodeError, TableError, OSError) as e:
            warnings.warn(
                f"ignoring tuned table {path}: {e} — dispatch falls back to "
                f"the roofline model (regenerate with "
                f"'python -m benchmarks.autotune_bench')",
                stacklevel=2)
            return cls(source=path)


# ---------------------------------------------------------------------------
# Default (committed) table
# ---------------------------------------------------------------------------

_default_table: TunedTable | None = None


def default_table_path() -> str:
    env = os.environ.get("REPRO_TUNED_TABLE")
    if env:
        return env
    here = os.path.abspath(__file__)          # src/repro/core/autotune.py
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(here))))
    return os.path.join(root, DEFAULT_TABLE_NAME)


def default_tuned_table() -> TunedTable:
    """The committed table, loaded once per process (lazily)."""
    global _default_table
    if _default_table is None:
        _default_table = TunedTable.load(default_table_path())
    return _default_table


def set_default_tuned_table(table: TunedTable | None) -> None:
    """Override (or with None, force a reload of) the process-wide table."""
    global _default_table
    _default_table = table


def resolve_table(tuned) -> TunedTable | None:
    """The table a ``tuned=`` argument denotes: "default" → the committed
    table, None → disabled (pure roofline), else the TunedTable itself."""
    if tuned is None:
        return None
    if tuned == "default":
        return default_tuned_table()
    return tuned


def dtype_key(dtype) -> str:
    return jnp.dtype(dtype).name


# ---------------------------------------------------------------------------
# The measured search
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Candidate:
    backend: str
    fuse: int = 1
    block_h: int | None = None
    rim: str | None = None


def _median_seconds(fn, x, *, warmup: int = 1, repeats: int = 3) -> float:
    """The hillclimb lower-and-measure harness, distilled: compile outside
    the timed region, then median of ``repeats`` timed calls."""
    for _ in range(warmup):
        jax.block_until_ready(fn(x))
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def schedule_candidates(
    spec: StencilSpec,
    grid_shape: tuple[int, ...],
    iters: int,
    *,
    mode: BoundaryMode = BoundaryMode.MASK,
    bc: DirichletBC | float | None = 0.0,
    device_kind: str | None = None,
) -> list[Candidate]:
    """Legal (backend, fuse, block_h, rim) schedules for one cell.

    ``halo`` is excluded (a distribution strategy, tuned per mesh not per
    host) and so is the ``reference`` oracle.  The 2D Pallas paths get the
    full schedule sweep when they would compile natively; when they would
    run interpreted only one schedule is measured — the row exists to be
    *recorded as interpreted*, not to compete.
    """
    from repro.core.plan import BACKENDS, backend_support
    from repro.kernels.tiling import default_interpret, resident_fits

    interp = default_interpret(None)
    out: list[Candidate] = []
    for backend in BACKENDS:
        if backend in ("reference", "halo"):
            continue
        if not backend_support(backend, spec, grid_shape=grid_shape,
                               mode=mode, bc=bc):
            continue
        sweeps = backend in ("pallas", "pallas_fused") and spec.ndim == 2 \
            and not spec.is_variable
        if not sweeps:
            out.append(Candidate(backend))
            continue
        if interp:
            out.append(Candidate(backend, fuse=1))
            continue
        for block_h in BLOCK_H_CANDIDATES:
            for fuse in FUSE_CANDIDATES:
                if iters % fuse:
                    continue
                out.append(Candidate(backend, fuse, block_h, "trapezoid"))
        if resident_fits(grid_shape):
            for fuse in RESIDENT_FUSE_CANDIDATES:
                if iters % fuse:
                    continue
                out.append(Candidate(backend, fuse, rim="resident"))
    return out


def measure_candidate(
    spec: StencilSpec,
    grid_shape: tuple[int, ...],
    cand: Candidate,
    *,
    iters: int,
    dtype=jnp.float32,
    mode: BoundaryMode = BoundaryMode.MASK,
    bc: DirichletBC | float | None = 0.0,
    batch: int = 1,
    repeats: int = 3,
    device_kind: str | None = None,
    mesh=None,
) -> TunedEntry:
    """Lower one schedule through ``make_plan`` and time it.

    ``mesh`` is required for (and only used by) halo candidates; the entry
    records its (n_row, n_col) tiling so lookups stay mesh-exact.
    """
    from repro.core.plan import _mesh_tiling, make_plan
    if device_kind is None:
        device_kind = jax.default_backend()
    plan = make_plan(
        spec, grid_shape, backend=cand.backend, bc=bc, mode=mode,
        iters=iters, fuse=cand.fuse if cand.rim or cand.fuse > 1 else None,
        block_h=cand.block_h, rim=cand.rim, dtype=dtype, mesh=mesh,
        tuned=None)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((batch, *grid_shape)), dtype)
    sec = _median_seconds(plan, x, repeats=repeats)
    return TunedEntry(
        device_kind=device_kind,
        family=spec_family(spec),
        bucket=shape_bucket(tuple(grid_shape)),
        dtype=dtype_key(dtype),
        backend=cand.backend,
        us_per_iter=sec / iters * 1e6,
        fuse=plan.fuse,
        block_h=cand.block_h,
        rim=cand.rim,
        interpreted=plan.interpreted,
        iters=iters,
        mesh=_mesh_tiling(mesh) if cand.backend == "halo" else None,
    )


def autotune_cell(
    spec: StencilSpec,
    grid_shape: tuple[int, ...],
    *,
    iters: int = 32,
    dtype=jnp.float32,
    mode: BoundaryMode = BoundaryMode.MASK,
    bc: DirichletBC | float | None = 0.0,
    table: TunedTable | None = None,
    repeats: int = 3,
    verbose: bool = False,
) -> TunedTable:
    """Measure every legal schedule for one cell into ``table``."""
    if table is None:
        table = TunedTable()
    for cand in schedule_candidates(spec, grid_shape, iters, mode=mode,
                                    bc=bc):
        try:
            entry = measure_candidate(spec, grid_shape, cand, iters=iters,
                                      dtype=dtype, mode=mode, bc=bc,
                                      repeats=repeats)
        except Exception as e:  # a candidate that fails to lower is skipped
            warnings.warn(f"autotune: candidate {cand} failed: {e}",
                          stacklevel=2)
            continue
        table.add(entry)
        if verbose:
            tag = " (interp)" if entry.interpreted else ""
            print(f"# tuned {entry.family} {entry.bucket} "
                  f"{cand.backend}/f{entry.fuse}"
                  f"{f'/b{cand.block_h}' if cand.block_h else ''}"
                  f"{f'/{cand.rim}' if cand.rim else ''}: "
                  f"{entry.us_per_iter:.1f} us/iter{tag}")
    return table


def halo_schedule_candidates(
    spec: StencilSpec,
    grid_shape: tuple[int, ...],
    mesh_tiling: tuple[int, int],
    iters: int,
) -> list[Candidate]:
    """Legal halo fuse depths for one (grid, mesh) cell: each candidate must
    divide the chunk and keep the exchanged depth within the local tile."""
    from repro.core.distributed import max_halo_fuse
    n_row, n_col = mesh_tiling
    if grid_shape[0] % n_row or grid_shape[1] % n_col:
        return []
    deepest = max_halo_fuse(spec.radius, grid_shape[0] // n_row,
                            grid_shape[1] // n_col)
    return [Candidate("halo", fuse=f) for f in HALO_FUSE_CANDIDATES
            if f <= deepest and iters % f == 0]


def autotune_halo_cell(
    spec: StencilSpec,
    grid_shape: tuple[int, ...],
    mesh,
    *,
    iters: int = 32,
    dtype=jnp.float32,
    bc: DirichletBC | float | None = 0.0,
    table: TunedTable | None = None,
    repeats: int = 3,
    verbose: bool = False,
) -> TunedTable:
    """Measure the halo fuse-depth sweep for one cell on ``mesh``.

    The distributed analogue of :func:`autotune_cell`: entries carry the
    mesh tiling so they only ever apply to the mesh shape they were measured
    on.  Run on the forced-8-host-device mesh (``scaling_bench.py
    --write-tuned``) to persist halo schedules into the committed table.
    """
    from repro.core.plan import _mesh_tiling
    if table is None:
        table = TunedTable()
    tiling = _mesh_tiling(mesh)
    for cand in halo_schedule_candidates(spec, grid_shape, tiling, iters):
        try:
            entry = measure_candidate(spec, grid_shape, cand, iters=iters,
                                      dtype=dtype, bc=bc, repeats=repeats,
                                      mesh=mesh)
        except Exception as e:
            warnings.warn(f"autotune: halo candidate {cand} failed: {e}",
                          stacklevel=2)
            continue
        table.add(entry)
        if verbose:
            print(f"# tuned {entry.family} {entry.bucket} halo/f{entry.fuse}"
                  f" @ mesh {tiling[0]}x{tiling[1]}: "
                  f"{entry.us_per_iter:.1f} us/iter")
    return table


# ---------------------------------------------------------------------------
# Validation (scripts/ci.sh --tune-check)
# ---------------------------------------------------------------------------

def validate_table(data: dict) -> list[str]:
    """Schema + legality errors for a raw table dict; [] means valid.

    Beyond the structural schema, every entry must still map to a legal
    ``backend_support`` cell — a backend renamed or a support rule tightened
    after the table was generated must fail CI, not silently misroute.
    """
    from repro.core.plan import BACKENDS, backend_support
    errors: list[str] = []
    try:
        table = TunedTable.parse(data)
    except TableError as e:
        return [str(e)]
    for i, e in enumerate(table.entries):
        where = f"entry {i} ({e.backend} @ {e.family} {e.bucket})"
        if e.backend not in BACKENDS:
            errors.append(f"{where}: unknown backend {e.backend!r}")
            continue
        if e.us_per_iter <= 0:
            errors.append(f"{where}: non-positive us_per_iter")
        if e.fuse < 1:
            errors.append(f"{where}: fuse must be >= 1")
        if any(b < 1 for b in e.bucket):
            errors.append(f"{where}: malformed bucket")
            continue
        if e.backend == "halo":
            if e.mesh is None:
                errors.append(f"{where}: halo entries must record the mesh "
                              f"tiling they were measured on")
                continue
            if len(e.mesh) != 2 or any(m < 1 for m in e.mesh):
                errors.append(f"{where}: malformed mesh {e.mesh}")
                continue
        elif e.mesh is not None:
            errors.append(f"{where}: mesh is a halo-only field "
                          f"(single-device schedules transfer across meshes)")
            continue
        try:
            rep = family_representative(e.family, e.bucket)
        except TableError as err:
            errors.append(f"{where}: {err}")
            continue
        sup = backend_support(e.backend, rep, grid_shape=e.bucket,
                              mode=BoundaryMode.MASK, bc=0.0,
                              mesh=e.mesh)
        if not sup:
            errors.append(f"{where}: no longer a legal backend_support "
                          f"cell: {sup.reason}")
    return errors


def check_table_file(path: str) -> list[str]:
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"cannot read {path}: {e}"]
    return validate_table(data)


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        description="validate a TUNED_stencil.json artifact")
    ap.add_argument("--check", metavar="PATH", default=None,
                    help="table to validate (default: the committed table)")
    args = ap.parse_args(argv)
    path = args.check or default_table_path()
    errors = check_table_file(path)
    if errors:
        for e in errors:
            print(f"TUNE-CHECK FAIL: {e}")
        return 1
    with open(path) as f:
        n = len(json.load(f).get("entries", []))
    print(f"tune-check OK: {path} ({n} entries, schema {SCHEMA_VERSION})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
