"""Pure-jnp direct stencil application — the oracle every encoding must match.

``apply_stencil`` computes the operator by shifted adds (no conv, no matmul),
with explicit Dirichlet boundary handling.  All encodings (dense, conv,
Pallas kernels, distributed halo-exchange) are validated against this.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.boundary import DirichletBC
from repro.core.stencil import StencilSpec, WeightField


def _shift(x: jnp.ndarray, offset: tuple[int, ...]) -> jnp.ndarray:
    """x shifted so result[i] = x[i + offset], zero-filled at the edges."""
    for d, o in enumerate(offset):
        if o == 0:
            continue
        n = x.shape[d]
        pad = [(0, 0)] * x.ndim
        if o > 0:
            # result[i] = x[i+o]: drop the first o, pad at the end.
            sl = [slice(None)] * x.ndim
            sl[d] = slice(o, n)
            pad[d] = (0, o)
        else:
            sl = [slice(None)] * x.ndim
            sl[d] = slice(0, n + o)
            pad[d] = (-o, 0)
        x = jnp.pad(x[tuple(sl)], pad)
    return x


def apply_stencil(x: jnp.ndarray, spec: StencilSpec,
                  fields: jnp.ndarray | None = None) -> jnp.ndarray:
    """One raw stencil application with zero (implicit) padding outside.

    Scalar taps contribute ``w * shift(x, off)``; per-cell weight fields
    contribute ``w[i] * x[i + off]`` (the field is indexed at the *output*
    cell) — this is the oracle the variable-coefficient conformance cells
    cross-check every encoding against.

    ``fields`` optionally overrides the spec's per-cell values: a (V, *grid)
    stack in canonical tap order (see ``StencilSpec.field_stack``).  It may
    be traced, which makes this the differentiable executor for the adjoint.
    """
    if spec.is_variable and spec.weights_shape != x.shape:
        raise ValueError(
            f"spec {spec.name} carries {spec.weights_shape}-shaped weight "
            f"fields but the grid is {x.shape}")
    acc = jnp.zeros_like(x)
    k = 0
    for off, w in spec.taps:
        if isinstance(w, WeightField):
            if fields is not None:
                wt = jnp.asarray(fields[k], x.dtype)
            else:
                wt = jnp.asarray(w.values, x.dtype)
            k += 1
        else:
            wt = jnp.asarray(w, x.dtype)
        acc = acc + wt * _shift(x, off)
    return acc


def jacobi_step(x: jnp.ndarray, spec: StencilSpec, bc: DirichletBC,
                fields: jnp.ndarray | None = None,
                source: jnp.ndarray | None = None) -> jnp.ndarray:
    """One Jacobi iteration with Dirichlet BCs: interior updated, shell held.

    With a ``source`` term the interior update becomes ``S x + s`` (the
    fixed-point form of an inhomogeneous problem); the shell stays pinned to
    the Dirichlet value either way.
    """
    out = apply_stencil(x, spec, fields)
    if source is not None:
        out = out + source
    return bc.apply_mask_trick(out)


def jacobi_reference(
    x0: jnp.ndarray, spec: StencilSpec, bc: DirichletBC, iterations: int
) -> jnp.ndarray:
    """``iterations`` Jacobi steps, plain Python loop (oracle — not for perf)."""
    x = bc.set_boundary(x0)
    for _ in range(iterations):
        x = jacobi_step(x, spec, bc)
    return x
