"""Iterative solver engine — the paper's time loop as one compiled program.

The paper's headline numbers are not one stencil application but an entire
Jacobi *solve* run to convergence on the wafer (Table 1 / Fig 6): thousands
of timesteps resident on-device, with the residual checked only periodically
so the hot loop never leaves the fabric.  This module is that time dimension
for the PR 1 dispatcher: ``solve(spec, x0, ...)`` lowers the spec through any
``make_plan`` backend and runs the whole iteration loop inside a single
``lax.while_loop``, so host round-trips happen once per *solve*, not once per
step.

Structure of a solve:

  * the plan executes ``check_every`` stencil iterations per chunk (the hot
    loop — fully fused, jitted once, Pallas temporal blocking inside it);
  * between chunks the residual ``||x_{k+1} - x_k||`` (relative L2 / Linf,
    the paper's Jacobi criterion) is measured on-device;
  * a ``lax.while_loop`` carries (field, per-instance residuals, iteration
    counts, residual history) until every instance converges or ``max_iters``
    is exhausted.

Batched mode is native: ``x0`` may carry a leading instance axis (the
"millions of users" scenario — every backend chunk executor is vmapped over
it) and convergence is tracked *per instance*: an instance that converges is
frozen (its field stops updating, its history stops recording) while the
rest keep iterating, so a batched solve reproduces the per-instance results
of solving each problem alone.

Distribution rides the same entry point: ``backend="halo"`` with a device
mesh runs each chunk as the shard_map halo-exchange program from
``core/distributed.py``, with residuals computed on the sharded global
array — the whole distributed time loop is still one compiled program.

For the 2D Pallas paths the temporal fuse depth is auto-selected against the
PR 1 roofline model (``estimate_seconds(..., fuse=...)`` prices each depth's
HBM-traffic saving against its trapezoid rim recompute).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.boundary import BoundaryMode, DirichletBC
from repro.core.plan import (
    DEVICE_PROFILES,
    StencilPlan,
    choose_backend,
    estimate_seconds,
    make_plan,
)
from repro.core.stencil import StencilSpec

_FUSE_CANDIDATES = (16, 8, 4, 2, 1)
_DEFAULT_CHECK_EVERY = 16


@dataclasses.dataclass
class SolveResult:
    """Outcome of one :meth:`Solver.solve` call.

    Scalar-vs-array convention: for an unbatched ``x0`` (bare grid) the
    per-instance fields are Python scalars; for a batched ``x0`` they are
    arrays over the instance axis.

    Attributes:
      x: final field, same shape as ``x0``.
      iterations: stencil iterations actually run (a multiple of
        ``check_every``; frozen instances stop counting when they converge).
      converged: whether the residual criterion was met before ``max_iters``.
      residual: last measured residual (absolute update norm).
      residual_history: one row per executed chunk; entry ``k`` is the
        residual measured after chunk ``k`` (NaN for instances already
        frozen).  Empty for fixed-iteration solves.
      backend/fuse/check_every: what actually ran.
      wall_seconds: wall time of the solve call (includes compilation on the
        first call through a given Solver).
      est_seconds: the roofline model's estimate for the iterations run.
      costs: per-backend cost table when ``backend="auto"`` chose.
    """

    x: jnp.ndarray
    iterations: int | np.ndarray
    converged: bool | np.ndarray
    residual: float | np.ndarray
    residual_history: np.ndarray
    backend: str
    fuse: int
    check_every: int
    wall_seconds: float
    est_seconds: float
    costs: dict[str, float]


def select_fuse(backend: str, spec: StencilSpec, grid_shape: tuple[int, ...],
                check_every: int, device_kind: str | None = None,
                tuned="default", dtype=jnp.float32, mesh=None) -> int | None:
    """Temporal fuse depth for one chunk: measured if tuned, else roofline.

    The 2D Pallas paths and ``halo`` fuse; every other backend gets ``None``
    (the plan records fuse=1).  A tuned-table entry for this cell whose
    backend matches supplies the measured depth first (clamped to the
    largest divisor of ``check_every`` so chunk boundaries land on whole
    fused passes); the roofline model prices the candidate depths otherwise.

    For ``halo`` the depth is additionally clamped to what the local tile
    can host (``max_halo_fuse``) on the (n_row, n_col) tiling of ``mesh``,
    tuned entries are matched mesh-exactly, and the roofline prices the
    communication term each depth divides.
    """
    halo = backend == "halo" and spec.ndim == 2
    if not halo and (backend not in ("pallas", "pallas_fused")
                     or spec.ndim != 2):
        return None
    if device_kind is None:
        device_kind = jax.default_backend()

    mesh_shape = deepest = None
    if halo:
        from repro.core.distributed import max_halo_fuse
        from repro.core.plan import _mesh_tiling
        mesh_shape = _mesh_tiling(mesh) if mesh is not None else None
        n_row, n_col = mesh_shape or (1, 1)
        if grid_shape[0] % n_row or grid_shape[1] % n_col:
            return None
        deepest = max_halo_fuse(spec.radius, grid_shape[0] // n_row,
                                grid_shape[1] // n_col)

    from repro.core import autotune
    table = autotune.resolve_table(tuned)
    if table is not None and len(table):
        entry = table.lookup(device_kind, autotune.spec_family(spec),
                             tuple(grid_shape), autotune.dtype_key(dtype),
                             mesh_shape=mesh_shape)
        if entry is not None and entry.backend == backend and entry.fuse >= 1:
            f = min(entry.fuse, check_every)
            if deepest is not None:
                f = min(f, deepest)
            while check_every % f:
                f -= 1
            return f

    device = DEVICE_PROFILES.get(device_kind, DEVICE_PROFILES["cpu"])
    candidates = [f for f in _FUSE_CANDIDATES if check_every % f == 0
                  and (deepest is None or f <= deepest)]
    return min(candidates,
               key=lambda f: estimate_seconds(backend, spec, grid_shape,
                                              check_every, device, fuse=f,
                                              mesh_shape=mesh_shape))


class Solver:
    """A prepared run-to-convergence executor for one (spec, grid, backend).

    Construction does all one-time work — backend choice, fuse-depth
    selection, plan building — and the first :meth:`solve` call compiles the
    full time loop; repeated solves (parameter sweeps, batched workloads)
    pay only compiled execution.

    Convergence: an instance is converged when

        ||x_{k+1} - x_k||  <=  atol + rtol * ||x_{k+1}||

    in the chosen norm (``"l2"`` or ``"linf"``), checked every
    ``check_every`` iterations.  ``rtol=None, atol=None`` disables checking
    entirely: the solve runs exactly ``max_iters`` iterations as one fused
    chunk (the benchmark / fixed-step mode).
    """

    def __init__(
        self,
        spec: StencilSpec,
        grid_shape: tuple[int, ...],
        *,
        backend: str = "auto",
        bc: DirichletBC | float | None = 0.0,
        mode: BoundaryMode = BoundaryMode.MASK,
        rtol: float | None = 1e-5,
        atol: float | None = 0.0,
        norm: str = "l2",
        check_every: int | None = None,
        # iteration budget; the loop runs floor(max_iters / check_every)
        # whole chunks, so the budget rounds DOWN to a multiple of
        # check_every (a convergent solve never exceeds max_iters)
        max_iters: int = 10_000,
        fuse: int | None = None,
        dtype=jnp.float32,
        mesh=None,
        interpret: bool | None = None,
        device_kind: str | None = None,
        tuned="default",
    ):
        if norm not in ("l2", "linf"):
            raise ValueError(f"norm must be 'l2' or 'linf', got {norm!r}")
        if max_iters < 1:
            raise ValueError("max_iters must be >= 1")
        if check_every is not None and check_every < 1:
            raise ValueError("check_every must be >= 1")
        self.spec = spec
        self.grid_shape = tuple(grid_shape)
        self.mode = mode
        self.norm = norm
        self.fixed = rtol is None and atol is None
        self.rtol = 0.0 if rtol is None else float(rtol)
        self.atol = 0.0 if atol is None else float(atol)
        if not self.fixed and self.rtol <= 0.0 and self.atol <= 0.0:
            raise ValueError(
                "unsatisfiable convergence criterion (rtol and atol both "
                "zero/None): set one > 0, or pass rtol=None, atol=None for "
                "fixed-iteration mode")
        self.max_iters = int(max_iters)
        self.dtype = dtype
        self.device_kind = device_kind

        if self.fixed:
            # One chunk of exactly max_iters iterations; no residual pass.
            self.check_every = self.max_iters
        else:
            self.check_every = (min(_DEFAULT_CHECK_EVERY, self.max_iters)
                                if check_every is None
                                else min(int(check_every), self.max_iters))
        self.n_chunks = max(1, self.max_iters // self.check_every)

        self.costs: dict[str, float] = {}
        was_auto = backend == "auto"
        if backend == "auto":
            # Price the whole solve (max_iters), not one chunk — fusion and
            # fixed per-iteration overheads amortize over the full loop —
            # but at a fuse depth a check_every-sized chunk can actually run,
            # not the phantom depth _resolve_fuse(max_iters) would pick.
            pricing_fuse = fuse
            if pricing_fuse is None:
                pricing_fuse = select_fuse("pallas_fused", spec,
                                           self.grid_shape, self.check_every,
                                           device_kind, tuned=tuned)
            backend, self.costs = choose_backend(
                spec, self.grid_shape, mode=mode, bc=bc,
                iters=self.max_iters, device_kind=device_kind, mesh=mesh,
                fuse=pricing_fuse, dtype=dtype, interpret=interpret,
                tuned=tuned)

        if fuse is None:
            fuse = select_fuse(backend, spec, self.grid_shape,
                               self.check_every, device_kind, tuned=tuned,
                               dtype=dtype, mesh=mesh)
        # A measured entry for this cell carries the rest of the schedule
        # (block shape, rim strategy) beside the fuse depth select_fuse
        # already took from it.
        block_h = rim = None
        entry = None
        from repro.core import autotune
        from repro.core.plan import _mesh_tiling
        table = autotune.resolve_table(tuned)
        if table is not None and len(table):
            entry = table.lookup(
                device_kind or jax.default_backend(),
                autotune.spec_family(spec), self.grid_shape,
                autotune.dtype_key(dtype),
                mesh_shape=_mesh_tiling(mesh) if mesh is not None else None)
            if entry is not None and entry.backend == backend:
                block_h, rim = entry.block_h, entry.rim
        # (an explicit fuse that does not divide check_every is rejected by
        # make_plan's iters/fuse divisibility check)
        self.plan: StencilPlan = make_plan(
            spec, self.grid_shape, backend=backend, bc=bc, mode=mode,
            iters=self.check_every, fuse=fuse, dtype=dtype, mesh=mesh,
            interpret=interpret, device_kind=device_kind, tuned=tuned,
            block_h=block_h, rim=rim)
        if was_auto:
            # The solver resolved "auto" itself (to price the whole solve),
            # so the plan saw an explicit backend name — restore where the
            # choice actually came from.
            self.plan.source = ("tuned" if entry is not None
                                and entry.backend == backend else "roofline")
        self.backend = self.plan.backend
        self.fuse = self.plan.fuse
        self.mesh_shape = _mesh_tiling(mesh) if mesh is not None else None
        if not self.fixed:
            self._loop = jax.jit(self._build_loop())

    # -- the compiled while_loop ------------------------------------------

    def _build_loop(self):
        plan = self.plan
        n_chunks, check_every = self.n_chunks, self.check_every
        rtol, atol = self.rtol, self.atol
        linf = self.norm == "linf"

        def grid_norm(v, axes):
            v = v.astype(jnp.float32)
            if linf:
                return jnp.max(jnp.abs(v), axis=axes)
            return jnp.sqrt(jnp.sum(v * v, axis=axes))

        def loop(x0, fields=None, source=None, bc_value=None):
            axes = tuple(range(1, x0.ndim))
            b = x0.shape[0]
            state = (
                jnp.int32(0),                              # chunks executed
                x0,                                        # field
                jnp.ones((b,), bool),                      # still iterating?
                jnp.full((b,), jnp.inf, jnp.float32),      # last residual
                jnp.zeros((b,), jnp.int32),                # iterations run
                jnp.full((n_chunks, b), jnp.nan, jnp.float32),  # history
            )

            def cond(s):
                k, _, active, *_ = s
                return (k < n_chunks) & jnp.any(active)

            def body(s):
                k, x, active, res, iters, hist = s
                y = plan(x, fields=fields, source=source, bc_value=bc_value)
                err = grid_norm(y - x, axes)
                done = err <= atol + rtol * grid_norm(y, axes)
                keep = active.reshape(active.shape + (1,) * (x.ndim - 1))
                x = jnp.where(keep, y, x)           # frozen instances hold
                res = jnp.where(active, err, res)
                hist = hist.at[k].set(jnp.where(active, err, jnp.nan))
                iters = iters + jnp.where(active, check_every, 0)
                active = active & ~done
                return (k + 1, x, active, res, iters, hist)

            return jax.lax.while_loop(cond, body, state)

        return loop

    # -- public API --------------------------------------------------------

    def run(self, x0: jnp.ndarray, *, fields=None, source=None,
            bc_value=None):
        """Trace-safe solve: ``(x, iterations, converged, residual)`` arrays.

        The differentiable / jittable core of :meth:`solve` — no host sync,
        no numpy conversion, no timing.  Operands beyond ``x0`` are runtime
        plan operands (per-cell weight ``fields``, additive ``source``,
        Dirichlet ``bc_value``) and may be traced; a plan that does not take
        an operand rejects a non-None value (see ``StencilPlan.operands``).
        The adjoint machinery (``core/adjoint.py``) builds on this.
        """
        x0 = jnp.asarray(x0, self.dtype)
        squeeze = x0.ndim == self.spec.ndim
        if squeeze:
            x0 = x0[None]
        if x0.shape[1:] != self.grid_shape:
            raise ValueError(
                f"solver built for grid {self.grid_shape}, got {x0.shape[1:]}")
        b = x0.shape[0]
        if self.fixed:
            x = self.plan(x0, fields=fields, source=source, bc_value=bc_value)
            iters = jnp.full((b,), self.max_iters, jnp.int32)
            converged = jnp.zeros((b,), bool)
            res = jnp.full((b,), jnp.nan, jnp.float32)
        else:
            _, x, active, res, iters, _ = self._loop(
                x0, fields, source, bc_value)
            converged = ~active
        if squeeze:
            return x[0], iters[0], converged[0], res[0]
        return x, iters, converged, res

    def solve(self, x0: jnp.ndarray, *, fields=None, source=None,
              bc_value=None) -> SolveResult:
        """Run the time loop from ``x0`` ((batch, *grid) or bare (*grid))."""
        x0 = jnp.asarray(x0, self.dtype)
        squeeze = x0.ndim == self.spec.ndim
        if squeeze:
            x0 = x0[None]
        if x0.shape[1:] != self.grid_shape:
            raise ValueError(
                f"solver built for grid {self.grid_shape}, got {x0.shape[1:]}")
        b = x0.shape[0]

        t0 = time.perf_counter()
        if self.fixed:
            x = self.plan(x0, fields=fields, source=source, bc_value=bc_value)
            jax.block_until_ready(x)
            wall = time.perf_counter() - t0
            iterations = np.full((b,), self.max_iters, np.int64)
            converged = np.zeros((b,), bool)
            residual = np.full((b,), np.nan, np.float32)
            history = np.empty((0, b), np.float32)
        else:
            k, x, active, res, iters, hist = self._loop(
                x0, fields, source, bc_value)
            jax.block_until_ready(x)
            wall = time.perf_counter() - t0
            iterations = np.asarray(iters, np.int64)
            converged = ~np.asarray(active)
            residual = np.asarray(res)
            history = np.asarray(hist)[: int(k)]

        device = DEVICE_PROFILES.get(
            self.device_kind or jax.default_backend(), DEVICE_PROFILES["cpu"])
        est = estimate_seconds(
            self.backend, self.spec, self.grid_shape,
            max(int(iterations.max()), 1), device, fuse=self.fuse,
            mesh_shape=self.mesh_shape)

        if squeeze:
            return SolveResult(
                x=x[0], iterations=int(iterations[0]),
                converged=bool(converged[0]), residual=float(residual[0]),
                residual_history=history[:, 0], backend=self.backend,
                fuse=self.fuse, check_every=self.check_every,
                wall_seconds=wall, est_seconds=est, costs=self.costs)
        return SolveResult(
            x=x, iterations=iterations, converged=converged,
            residual=residual, residual_history=history,
            backend=self.backend, fuse=self.fuse,
            check_every=self.check_every, wall_seconds=wall,
            est_seconds=est, costs=self.costs)

    __call__ = solve


def solve(
    spec: StencilSpec,
    x0: jnp.ndarray,
    *,
    backend: str = "auto",
    bc: DirichletBC | float | None = 0.0,
    mode: BoundaryMode = BoundaryMode.MASK,
    rtol: float | None = 1e-5,
    atol: float | None = 0.0,
    norm: str = "l2",
    check_every: int | None = None,
    max_iters: int = 10_000,
    fuse: int | None = None,
    mesh=None,
    interpret: bool | None = None,
    device_kind: str | None = None,
    tuned="default",
    fields=None,
    source=None,
    bc_value=None,
) -> SolveResult:
    """One-shot iterative solve: run ``spec``'s time loop from ``x0``.

    ``x0`` is (batch, *grid) or bare (*grid); see :class:`Solver` for the
    convergence criterion and :class:`SolveResult` for what comes back.
    Build a :class:`Solver` directly to amortize compilation over repeated
    solves.  ``fields`` / ``source`` / ``bc_value`` are runtime plan
    operands (per-cell weights, additive source term, Dirichlet value); for
    a *differentiable* solve use ``core.adjoint.implicit_solve``.
    """
    x0 = jnp.asarray(x0)
    if x0.ndim not in (spec.ndim, spec.ndim + 1):
        raise ValueError(
            f"x0.ndim={x0.ndim} incompatible with a {spec.ndim}D spec "
            f"(expect grid or batch+grid)")
    grid_shape = tuple(x0.shape[-spec.ndim:])
    dtype = x0.dtype if jnp.issubdtype(x0.dtype, jnp.floating) else jnp.float32
    solver = Solver(
        spec, grid_shape, backend=backend, bc=bc, mode=mode, rtol=rtol,
        atol=atol, norm=norm, check_every=check_every, max_iters=max_iters,
        fuse=fuse, dtype=dtype, mesh=mesh, interpret=interpret,
        device_kind=device_kind, tuned=tuned)
    return solver.solve(x0, fields=fields, source=source, bc_value=bc_value)
