"""Convolution-layer encoding of a stencil (paper Algorithm 2, Figures 2-4).

2D: the stencil's footprint window slides over the input
(``lax.conv_general_dilated``, NCHW / channels-first — the only layout the
CS-1 supported).  Non-zero Dirichlet BCs use the paper's mask trick
(BoundaryMode.MASK) because the Cerebras stack lacked ``tf.pad``; JAX has
``pad`` so BoundaryMode.PAD is also provided and compared in §Perf.

3D: the CS-1 only had Conv2D, so the third dimension maps onto the
*channels* axis (paper Figures 3-4).  A (dz,dx,dy) tap with weight w becomes
kernel[z_out, z_out+dz, 1+dx, 1+dy] = w — a banded Z_out×Z_in channel-mixing
matrix.  Z_out=Z_in=Z keeps the output 3D (Figure 4).  The band is dense in
storage: Z²·9 weights instead of 7, overhead we quantify against native 3D
conv in EXPERIMENTS §Perf.

Variable coefficients: a conv kernel is spatially invariant, so per-cell
weight fields cannot live *in* the kernel — but they can ride the same
tensor-op vocabulary via the *gather trick*: a one-hot kernel (one output
channel per varying tap) extracts each neighbour into a channel, and the
per-cell fields apply as an elementwise multiply-and-reduce over channels
(the same mul+add shape as the paper's mask trick).  Scalar taps stay in an
ordinary conv kernel, so a mixed spec costs one conv plus one gather.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.boundary import BoundaryMode, DirichletBC, runtime_bc_grids
from repro.core.stencil import StencilSpec, WeightField


def _seed_and_drive(grid, bc, bc_value, source, dtype, x0):
    """(seeded x, mask, drive) shared by the MASK-trick executors.

    Every mask-trick scan body computes ``y = conv(x) * mask + bc_grid``; a
    runtime source term and/or traced Dirichlet value fold into the same
    additive grid — ``drive = bc_grid + mask * source`` — so the jitted
    bodies need no changes to become differentiable in both operands.
    ``drive`` carries a leading broadcast axis ((1, *grid) or (B, *grid)
    for a batched source).
    """
    if bc_value is None:
        mask = bc.interior_mask(grid, dtype)
        bcg = bc.bc_grid(grid, dtype)
        x = jax.vmap(bc.set_boundary)(x0.astype(dtype))
    else:
        mask, bcg = runtime_bc_grids(grid, bc_value, dtype)
        x = x0.astype(dtype) * mask + bcg
    drive = bcg[None]
    if source is not None:
        drive = drive + mask * jnp.asarray(source, dtype)
    return x, mask, drive


# ---------------------------------------------------------------------------
# 2D conv encoding
# ---------------------------------------------------------------------------

def conv2d_kernel(spec: StencilSpec, dtype=np.float32) -> np.ndarray:
    """OIHW kernel (1,1,kh,kw) — Figure 2 of the paper for 2D Laplace."""
    if spec.ndim != 2:
        raise ValueError("conv2d_kernel needs a 2D spec")
    return spec.to_kernel(dtype)[None, None]


def conv2d_apply(x: jnp.ndarray, kernel: jnp.ndarray, padding: str = "SAME") -> jnp.ndarray:
    """One conv application.  x: (batch, C, H, W); kernel: OIHW."""
    return jax.lax.conv_general_dilated(
        x,
        kernel.astype(x.dtype),
        window_strides=(1, 1),
        padding=padding,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("iterations", "mode"))
def _conv_jacobi_2d(x, kernel, mask, bc_grid, iterations, mode):
    kh = kernel.shape[2]
    pad = (kh - 1) // 2

    if mode is BoundaryMode.MASK:
        def body(x, _):
            y = conv2d_apply(x, kernel, "SAME")
            # Paper §3: zero the convolved boundary, add the BC values back.
            y = y * mask + bc_grid
            return y, None
    elif mode is BoundaryMode.PAD:
        def body(x, _):
            # 'valid' conv on the interior; boundary shell re-written from x
            # itself (it holds the Dirichlet values, which never change).
            inner = conv2d_apply(x, kernel, "VALID")
            y = jnp.pad(inner, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
            y = y * mask + x * (1.0 - mask)
            return y, None
    else:
        raise ValueError(f"unsupported mode for conv encoding: {mode}")

    x, _ = jax.lax.scan(body, x, None, length=iterations)
    return x


def conv_jacobi_2d(
    x0: jnp.ndarray,
    spec: StencilSpec,
    bc: DirichletBC,
    iterations: int,
    mode: BoundaryMode = BoundaryMode.MASK,
    dtype=jnp.float32,
    *,
    source: jnp.ndarray | None = None,
    bc_value=None,
) -> jnp.ndarray:
    """Algorithm 2 of the paper.  x0: (batch, H, W) → (batch, H, W).

    ``source``/``bc_value`` are optional runtime (possibly traced) operands;
    they fold into the mask-trick drive grid, so they require
    ``BoundaryMode.MASK``.
    """
    if mode is BoundaryMode.PAD and spec.radius != 1:
        # With a 1-cell boundary shell, 'valid'+re-pad only reconstructs the
        # zero-padded semantics for radius-1 stencils; use MASK otherwise.
        raise ValueError("BoundaryMode.PAD requires a radius-1 stencil")
    if (source is not None or bc_value is not None) \
            and mode is not BoundaryMode.MASK:
        raise ValueError("runtime source/bc_value operands fold into the "
                         "mask-trick drive grid (BoundaryMode.MASK only)")
    grid = x0.shape[1:]
    kernel = jnp.asarray(conv2d_kernel(spec), dtype=dtype)
    x, mask, drive = _seed_and_drive(grid, bc, bc_value, source, dtype, x0)
    out = _conv_jacobi_2d(x[:, None], kernel, mask[None, None],
                          drive[:, None], iterations, mode)
    return out[:, 0]


# ---------------------------------------------------------------------------
# 3D via Conv2D channels (paper Figures 3-4)
# ---------------------------------------------------------------------------

def conv3d_channels_kernel(spec: StencilSpec, depth: int, dtype=np.float32) -> np.ndarray:
    """OIHW kernel (Z, Z, kh, kw) encoding a 3D stencil in Conv2D channels.

    Offsets are (dz, dx, dy): dz indexes the channel band, (dx,dy) the 2D
    window.  Output channel z reads input channels z+dz — the banded matrix
    of paper Figure 4.
    """
    if spec.ndim != 3:
        raise ValueError("conv3d_channels_kernel needs a 3D spec")
    if spec.is_variable:
        raise ValueError(
            "the channels-trick Conv2D shares its band weights across the "
            "whole X-Y plane; per-cell weight fields are not expressible — "
            "use conv3d_native, dense, or pallas")
    fz, fx, fy = spec.footprint
    lo = [min(off[d] for off, _ in spec.taps) for d in range(3)]
    ker = np.zeros((depth, depth, fx, fy), dtype=dtype)
    for (dz, dx, dy), w in spec.taps:
        for z_out in range(depth):
            z_in = z_out + dz
            if 0 <= z_in < depth:
                ker[z_out, z_in, dx - lo[1], dy - lo[2]] += w
    return ker


@functools.partial(jax.jit, static_argnames=("iterations",))
def _conv_jacobi_3d_channels(x, kernel, mask, bc_grid, iterations):
    def body(x, _):
        y = conv2d_apply(x, kernel, "SAME")
        y = y * mask + bc_grid
        return y, None
    x, _ = jax.lax.scan(body, x, None, length=iterations)
    return x


def conv_jacobi_3d_channels(
    x0: jnp.ndarray,
    spec: StencilSpec,
    bc: DirichletBC,
    iterations: int,
    dtype=jnp.float32,
    *,
    source: jnp.ndarray | None = None,
    bc_value=None,
) -> jnp.ndarray:
    """Paper's 3D approach.  x0: (batch, Z, X, Y); Z rides the channel axis.

    Note the channel band handles dz internally, so the *mask* must treat the
    Z faces as boundary too — the mask/bc grids are built on the full 3D
    shape and broadcast as (1, Z, X, Y).
    """
    grid = x0.shape[1:]  # (Z, X, Y)
    kernel = jnp.asarray(conv3d_channels_kernel(spec, depth=grid[0]), dtype=dtype)
    x, mask, drive = _seed_and_drive(grid, bc, bc_value, source, dtype, x0)
    return _conv_jacobi_3d_channels(x, kernel, mask[None], drive, iterations)


# ---------------------------------------------------------------------------
# Native 3D conv (beyond-paper: what the CS-1 stack could not express)
# ---------------------------------------------------------------------------

def conv3d_kernel(spec: StencilSpec, dtype=np.float32) -> np.ndarray:
    """OIDHW kernel (1,1,kz,kx,ky) for a native 3D convolution."""
    if spec.ndim != 3:
        raise ValueError("conv3d_kernel needs a 3D spec")
    return spec.to_kernel(dtype)[None, None]


@functools.partial(jax.jit, static_argnames=("iterations",))
def _conv_jacobi_3d_native(x, kernel, mask, bc_grid, iterations):
    def body(x, _):
        y = jax.lax.conv_general_dilated(
            x, kernel.astype(x.dtype), (1, 1, 1), "SAME",
            dimension_numbers=("NCDHW", "OIDHW", "NCDHW"),
            preferred_element_type=jnp.float32,
        ).astype(x.dtype)
        y = y * mask + bc_grid
        return y, None
    x, _ = jax.lax.scan(body, x, None, length=iterations)
    return x


def conv_jacobi_3d_native(
    x0: jnp.ndarray,
    spec: StencilSpec,
    bc: DirichletBC,
    iterations: int,
    dtype=jnp.float32,
    *,
    source: jnp.ndarray | None = None,
    bc_value=None,
) -> jnp.ndarray:
    """Native Conv3D path — the encoding the paper could not use on the CS-1."""
    grid = x0.shape[1:]
    kernel = jnp.asarray(conv3d_kernel(spec), dtype=dtype)
    x, mask, drive = _seed_and_drive(grid, bc, bc_value, source, dtype, x0)
    out = _conv_jacobi_3d_native(x[:, None], kernel, mask[None, None],
                                 drive[:, None], iterations)
    return out[:, 0]


# ---------------------------------------------------------------------------
# Variable-coefficient gather trick (2D conv and native 3D conv)
# ---------------------------------------------------------------------------

def split_var_kernels(spec: StencilSpec, dtype=np.float32):
    """Split a (possibly mixed) spec into conv-friendly pieces.

    Returns ``(scalar_kernel, gather_kernel, fields)``:

      scalar_kernel  (1, 1, *footprint) holding the constant taps (zeros if
                     every tap varies);
      gather_kernel  (V, 1, *footprint), one one-hot output channel per
                     varying tap — the conv that extracts each neighbour;
      fields         (V, *grid) stacked per-cell weight fields, in the same
                     channel order as ``gather_kernel``.
    """
    lo = [min(off[d] for off, _ in spec.taps) for d in range(spec.ndim)]
    fp = spec.footprint
    scalar = np.zeros((1, 1) + fp, dtype=dtype)
    onehots, fields = [], []
    for off, w in spec.taps:
        idx = tuple(o - l for o, l in zip(off, lo))
        if isinstance(w, WeightField):
            oh = np.zeros((1,) + fp, dtype=dtype)
            oh[(0,) + idx] = 1.0
            onehots.append(oh)
            fields.append(w.array)
        else:
            scalar[(0, 0) + idx] += w
    gather = np.stack(onehots) if onehots else np.zeros((0, 1) + fp, dtype)
    flds = (np.stack(fields).astype(dtype) if fields
            else np.zeros((0,) + (spec.weights_shape or ()), dtype))
    return scalar, gather, flds


@functools.partial(jax.jit, static_argnames=("iterations", "ndim"))
def _conv_var_jacobi(x, scalar_k, gather_k, fields, mask, bc_grid,
                     iterations, ndim):
    if ndim == 2:
        apply_ = conv2d_apply
    else:
        def apply_(v, k):
            return jax.lax.conv_general_dilated(
                v, k.astype(v.dtype), (1, 1, 1), "SAME",
                dimension_numbers=("NCDHW", "OIDHW", "NCDHW"),
                preferred_element_type=jnp.float32,
            ).astype(v.dtype)

    def body(x, _):
        y = apply_(x, scalar_k)
        g = apply_(x, gather_k)                       # (B, V, *grid)
        y = y + jnp.sum(g * fields[None], axis=1, keepdims=True)
        y = y * mask + bc_grid
        return y, None

    x, _ = jax.lax.scan(body, x, None, length=iterations)
    return x


def conv_var_jacobi(
    x0: jnp.ndarray,
    spec: StencilSpec,
    bc: DirichletBC,
    iterations: int,
    dtype=jnp.float32,
    *,
    fields: jnp.ndarray | None = None,
    source: jnp.ndarray | None = None,
    bc_value=None,
) -> jnp.ndarray:
    """Variable-coefficient Jacobi via the gather trick (MASK boundary mode).

    2D runs through Conv2D (NCHW); 3D through native Conv3D (NCDHW) — the
    channels-trick 3D path cannot express per-cell fields (its band weights
    are shared across the plane), which ``backend_support`` reports as a
    reasoned skip.  x0: (batch, *grid) → (batch, *grid).

    ``fields`` optionally overrides the spec's baked per-cell values with a
    runtime (V, *grid) stack — the stack was already an operand of the
    jitted body, so a traced override costs nothing and is differentiable.
    """
    if spec.ndim not in (2, 3):
        raise ValueError("conv gather trick supports 2D and 3D specs")
    grid = x0.shape[1:]
    if spec.weights_shape != grid:
        raise ValueError(
            f"spec {spec.name} carries {spec.weights_shape}-shaped weight "
            f"fields but the grid is {grid}")
    scalar_k, gather_k, baked = split_var_kernels(spec)
    f = jnp.asarray(baked if fields is None else fields, dtype)
    x, mask, drive = _seed_and_drive(grid, bc, bc_value, source, dtype, x0)
    out = _conv_var_jacobi(
        x[:, None], jnp.asarray(scalar_k, dtype), jnp.asarray(gather_k, dtype),
        f, mask[None, None], drive[:, None], iterations, spec.ndim)
    return out[:, 0]
