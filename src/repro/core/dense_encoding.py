"""Dense-layer encoding of a stencil (paper Algorithm 1 / Figure 1).

The grid is flattened to a vector of length N and one Jacobi iteration becomes
a matrix–vector product with an N×N matrix W:

    out_flat = x_flat @ W,    W[j, i] = weight of x_j's contribution to out_i

Boundary conditions are encoded *inside the matrix*: rows/cols for boundary
cells form an identity block, so Dirichlet values persist through iterations
with no extra ops (the paper's stated advantage of this encoding).

The cost is what the paper measures: the matrix is O(N²) storage and one
iteration performs (2N-1) FLOPs per output element, nearly all redundant
(8191 vs 7 useful for X=Y=64).  We reproduce it faithfully — including the
"one layer per iteration" memory model that limited the CS-1 to 7 iterations
— and expose the waste in the roofline (EXPERIMENTS §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.boundary import DirichletBC
from repro.core.stencil import StencilSpec, WeightField


def build_dense_matrix(
    grid_shape: tuple[int, ...], spec: StencilSpec, dtype=np.float32,
    include_variable: bool = True,
) -> np.ndarray:
    """Materialize the N×N stencil matrix with identity boundary rows.

    Matches Figure 1 of the paper for 2D Laplace with X=Y=3: the only
    non-identity row is the interior cell, holding 0.25 at its four
    neighbours.  Variable-coefficient taps fold in for free: the matrix
    column for output cell ``i`` holds ``w_k(i)`` — spatial variation costs
    the dense encoding nothing, the paper's argument for it taken further.
    """
    if spec.ndim != len(grid_shape):
        raise ValueError(f"spec is {spec.ndim}D but grid is {len(grid_shape)}D")
    if spec.is_variable and spec.weights_shape != tuple(grid_shape):
        raise ValueError(
            f"spec {spec.name} carries {spec.weights_shape}-shaped weight "
            f"fields but the grid is {tuple(grid_shape)}")
    n = int(np.prod(grid_shape))
    w = np.zeros((n, n), dtype=dtype)
    interior = np.zeros(grid_shape, dtype=bool)
    interior[tuple(slice(1, -1) for _ in grid_shape)] = True

    strides = np.array([int(np.prod(grid_shape[d + 1 :])) for d in range(len(grid_shape))])
    for flat_i in range(n):
        idx = np.unravel_index(flat_i, grid_shape)
        if not interior[idx]:
            # Boundary cell: identity row — BC value persists (paper Fig 1).
            w[flat_i, flat_i] = 1.0
            continue
        for off, weight in spec.taps:
            nbr = np.array(idx) + np.array(off)
            if np.any(nbr < 0) or np.any(nbr >= np.array(grid_shape)):
                # Radius > 1: taps can reach past the grid even from interior
                # cells; zero-pad semantics means they contribute nothing
                # (without this check a negative index silently wraps).
                continue
            flat_j = int(np.dot(nbr, strides))
            # column = output, row = input (x @ W); per-cell fields are
            # indexed at the output cell
            if isinstance(weight, WeightField):
                if not include_variable:
                    continue
                wv = weight.array[idx]
            else:
                wv = weight
            w[flat_j, flat_i] += wv
    return w


def var_tap_indices(
    grid_shape: tuple[int, ...], spec: StencilSpec
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Scatter indices that place runtime per-cell fields into the matrix.

    Returns ``(tap_k, flat_j, flat_i)`` int32 arrays, one entry per
    (variable tap, interior output cell with in-bounds neighbour) pair, so a
    traced (V, *grid) field stack becomes matrix updates

        W = W0.at[flat_j, flat_i].add(fields.reshape(V, -1)[tap_k, flat_i])

    where ``W0 = build_dense_matrix(..., include_variable=False)``.  This is
    how the dense encoding takes weight fields as *operands* (differentiable,
    no rebuild) instead of baking them in at plan time.
    """
    n = int(np.prod(grid_shape))
    interior = np.zeros(grid_shape, dtype=bool)
    interior[tuple(slice(1, -1) for _ in grid_shape)] = True
    strides = np.array([int(np.prod(grid_shape[d + 1:]))
                        for d in range(len(grid_shape))])
    var_offsets = [off for off, w in spec.taps if isinstance(w, WeightField)]
    tap_k, flat_j, flat_i = [], [], []
    for flat in range(n):
        idx = np.unravel_index(flat, grid_shape)
        if not interior[idx]:
            continue
        for k, off in enumerate(var_offsets):
            nbr = np.array(idx) + np.array(off)
            if np.any(nbr < 0) or np.any(nbr >= np.array(grid_shape)):
                continue
            tap_k.append(k)
            flat_j.append(int(np.dot(nbr, strides)))
            flat_i.append(flat)
    return (np.asarray(tap_k, np.int32), np.asarray(flat_j, np.int32),
            np.asarray(flat_i, np.int32))


@functools.partial(jax.jit, static_argnames=("iterations",))
def dense_jacobi(
    x0: jnp.ndarray, matrix: jnp.ndarray, iterations: int,
    drive: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Algorithm 1: flatten, then ``iterations`` dense-layer applications.

    ``x0`` has shape (batch, *grid_shape).  The matmul accumulates in fp32
    (mixed precision, as on the CS-1).  ``drive`` is an optional flattened
    additive term per iteration ((n,) or (batch, n), zero on the boundary
    shell so the identity rows keep pinning the Dirichlet values) — the
    fixed-point form of an inhomogeneous problem, ``x <- x W + c``.
    """
    batch = x0.shape[0]
    grid_shape = x0.shape[1:]
    x = x0.reshape(batch, -1)
    def body(x, _):
        y = jnp.matmul(x, matrix, preferred_element_type=jnp.float32)
        if drive is not None:
            y = y + drive
        return y.astype(x0.dtype), None
    x, _ = jax.lax.scan(body, x, None, length=iterations)
    return x.reshape(batch, *grid_shape)


def dense_jacobi_with_bc(
    x0: jnp.ndarray,
    spec: StencilSpec,
    bc: DirichletBC,
    iterations: int,
    dtype=jnp.float32,
) -> jnp.ndarray:
    """Convenience wrapper: build matrix, seed BCs into x0, iterate."""
    grid_shape = x0.shape[1:]
    matrix = jnp.asarray(build_dense_matrix(grid_shape, spec), dtype=dtype)
    x0 = jax.vmap(bc.set_boundary)(x0.astype(dtype))
    return dense_jacobi(x0, matrix, iterations)


def dense_layer_bytes(grid_shape: tuple[int, ...], iterations: int, bytes_per_el: int = 2) -> int:
    """Memory the CS-1 model needed: one N² layer *per iteration* (paper §4).

    Reproduces the 7-iteration limit analytically: with N=4096 and fp16,
    7 iterations ≈ 235 MB of layer weights — at 27% fabric utilisation the
    Cerebras compiler could not place an 8th layer.
    """
    n = int(np.prod(grid_shape))
    return n * n * bytes_per_el * iterations
