"""Boundary-condition handling — the paper's mask trick and its alternatives.

The Cerebras TF stack lacked ``tf.pad`` and ``concatenate`` (paper §3), so
non-zero Dirichlet boundary conditions had to be applied as

    out = conv(x) * interior_mask + bc_values        (MASK mode)

where ``interior_mask`` is 1 in the interior and 0 on the boundary, and
``bc_values`` holds the Dirichlet values on the boundary and 0 inside.  This
costs 2N extra ops per iteration (one mul + one add per element).

JAX *does* have ``jnp.pad``; we therefore also implement:

  PAD    — 'valid' stencil application on an input padded with the BC values
           (the approach the paper says it *wanted*: pad + set boundary).
  MATRIX — BCs folded into the dense-encoding matrix (identity rows), the
           paper's dense-layer advantage: "the stencil matrix value can be
           set to 1 in order to maintain boundary conditions".

All modes compute identical results; MASK is the paper-faithful default for
the conv path and its overhead is quantified in EXPERIMENTS §Perf.
"""
from __future__ import annotations

import dataclasses
import enum

import jax.numpy as jnp
import numpy as np


class BoundaryMode(enum.Enum):
    MASK = "mask"      # paper-faithful: conv('same') then mask-mult + bc-add
    PAD = "pad"        # jnp.pad with BC values, stencil applied 'valid'
    MATRIX = "matrix"  # dense encoding only: identity rows in the matrix


@dataclasses.dataclass(frozen=True)
class DirichletBC:
    """Fixed boundary values on the outermost shell of the grid.

    ``value`` may be a scalar or a full-grid array whose boundary shell holds
    the BC values (interior entries are ignored).
    """

    value: float | jnp.ndarray = 0.0

    def interior_mask(self, shape: tuple[int, ...], dtype=jnp.float32) -> jnp.ndarray:
        """1 in the interior, 0 on the boundary shell (paper §3 'mask')."""
        m = np.zeros(shape, dtype=np.float32)
        inner = tuple(slice(1, -1) for _ in shape)
        m[inner] = 1.0
        return jnp.asarray(m, dtype=dtype)

    def bc_grid(self, shape: tuple[int, ...], dtype=jnp.float32) -> jnp.ndarray:
        """BC values on the boundary shell, 0 in the interior."""
        if isinstance(self.value, (int, float)):
            g = np.full(shape, float(self.value), dtype=np.float32)
            g = jnp.asarray(g, dtype=dtype)
        else:
            g = jnp.asarray(self.value, dtype=dtype)
            if g.shape != shape:
                raise ValueError(f"bc grid shape {g.shape} != {shape}")
        mask = self.interior_mask(shape, dtype)
        return g * (1.0 - mask)

    def apply_mask_trick(self, out: jnp.ndarray) -> jnp.ndarray:
        """The paper's post-iteration fixup: zero the boundary, add BCs back."""
        mask = self.interior_mask(out.shape, out.dtype)
        bc = self.bc_grid(out.shape, out.dtype)
        return out * mask + bc

    def set_boundary(self, x: jnp.ndarray) -> jnp.ndarray:
        """Write the BC values onto the boundary shell of ``x``."""
        mask = self.interior_mask(x.shape, x.dtype)
        bc = self.bc_grid(x.shape, x.dtype)
        return x * mask + bc


def runtime_bc_grids(shape: tuple[int, ...], bc_value,
                     dtype=jnp.float32) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(interior_mask, bc_grid) for a possibly-*traced* Dirichlet value.

    ``DirichletBC`` holds its value as static plan-build-time data; this is
    the runtime-operand counterpart: ``bc_value`` may be a Python scalar, a
    traced 0-d array, or a (possibly traced) full-grid array whose shell
    holds the values.  The returned ``bc_grid`` is a traced function of
    ``bc_value``, so gradients flow through it (the adjoint solve needs
    d(solution)/d(boundary value)).
    """
    m = np.zeros(shape, dtype=np.float32)
    m[tuple(slice(1, -1) for _ in shape)] = 1.0
    mask = jnp.asarray(m, dtype)
    v = jnp.asarray(bc_value, dtype)
    if v.ndim not in (0, len(shape)):
        raise ValueError(
            f"bc_value must be a scalar or a {len(shape)}D grid, got "
            f"shape {v.shape}")
    if v.ndim and v.shape != tuple(shape):
        raise ValueError(f"bc grid shape {v.shape} != {tuple(shape)}")
    return mask, v * (1.0 - mask)
