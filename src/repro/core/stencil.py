"""Stencil specification — the paper's computational object.

A stencil is a fixed pattern of weighted contributions from neighbouring grid
cells (paper §2): ``out[i] = sum_k w_k * x[i + off_k]``.  The paper's running
example is the Jacobi update for Laplace's equation for diffusion:

  2D (5-point):  out[i,j]   = 0.25*(x[i-1,j] + x[i+1,j] + x[i,j-1] + x[i,j+1])
  3D (7-point):  out[i,j,k] = (1/6)*(six face neighbours)

``StencilSpec`` is dimension-agnostic: offsets are integer tuples, weights are
floats.  Encodings (dense / conv / Pallas kernels) consume the same spec, so
every backend computes the same operator and can be cross-validated.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

Offset = tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class StencilSpec:
    """A fixed neighbourhood-weight pattern.

    Attributes:
      taps: tuple of (offset, weight) pairs — offset is an integer tuple (one
        entry per grid dim), weight the float contribution of that neighbour.
        A Mapping may be passed at construction; it is canonicalized to a
        sorted tuple so the spec is hashable (jit-static).
      name: for reporting.
    """

    taps: tuple[tuple[Offset, float], ...]
    name: str = "stencil"

    def __post_init__(self):
        taps = self.taps
        if isinstance(taps, Mapping):
            taps = tuple(sorted((tuple(o), float(w)) for o, w in taps.items()))
        else:
            taps = tuple(sorted((tuple(o), float(w)) for o, w in taps))
        object.__setattr__(self, "taps", taps)
        ndims = {len(o) for o, _ in self.taps}
        if len(ndims) != 1:
            raise ValueError(f"inconsistent offset ranks in {self.name}: {ndims}")

    @property
    def ndim(self) -> int:
        return len(self.taps[0][0])

    @property
    def radius(self) -> int:
        """Max Chebyshev distance of any tap — the halo depth one application needs."""
        return max(max(abs(c) for c in off) for off, _ in self.taps)

    @property
    def footprint(self) -> tuple[int, ...]:
        """Bounding-box shape of the kernel window (2r+1 per dim for symmetric taps)."""
        lo = [min(off[d] for off, _ in self.taps) for d in range(self.ndim)]
        hi = [max(off[d] for off, _ in self.taps) for d in range(self.ndim)]
        return tuple(h - l + 1 for l, h in zip(lo, hi))

    @property
    def useful_flops_per_point(self) -> int:
        """FLOPs that contribute to the result: one mul per tap + (taps-1) adds.

        For 2D Laplace (4 taps) this is 7 = 4 mul + 3 add, matching §4 of the
        paper ("7 useful calculations ... four multiplications and three
        additions").
        """
        n = len(self.taps)
        return 2 * n - 1

    def delivered_flops_per_point_conv(self) -> int:
        """FLOPs the *conv encoding* performs per output element.

        The conv kernel covers the full footprint including zero taps: one mul
        per window element + (window-1) adds.  For the 3×3 2D Laplace window
        this is 17, matching §4 of the paper.
        """
        w = int(np.prod(self.footprint))
        return 2 * w - 1

    def delivered_flops_per_point_dense(self, n_total: int) -> int:
        """FLOPs the *dense encoding* performs per output element: (2N-1).

        With X=Y=64 ⇒ N=4096 this is 8191, matching §4 of the paper.
        """
        return 2 * n_total - 1

    def to_kernel(self, dtype=np.float32) -> np.ndarray:
        """Materialize the footprint window as a dense array (the conv kernel).

        Figure 2 of the paper: for 2D Laplace this is the 3×3 array with 0.25
        on the four faces and zeros elsewhere.
        """
        lo = [min(off[d] for off, _ in self.taps) for d in range(self.ndim)]
        ker = np.zeros(self.footprint, dtype=dtype)
        for off, w in self.taps:
            idx = tuple(o - l for o, l in zip(off, lo))
            ker[idx] = w
        return ker


def laplace_jacobi(ndim: int) -> StencilSpec:
    """The paper's benchmark stencil: Jacobi iteration for Laplace's equation."""
    w = 1.0 / (2 * ndim)
    taps = {}
    for d in range(ndim):
        for s in (-1, 1):
            off = [0] * ndim
            off[d] = s
            taps[tuple(off)] = w
    return StencilSpec(taps=taps, name=f"laplace{ndim}d")


def star(ndim: int, weights_by_distance: Sequence[float], center: float = 0.0) -> StencilSpec:
    """Star stencil of arbitrary radius (e.g. higher-order finite differences)."""
    taps = {}
    if center != 0.0:
        taps[(0,) * ndim] = center
    for r, w in enumerate(weights_by_distance, start=1):
        if w == 0.0:
            continue
        for d in range(ndim):
            for s in (-r, r):
                off = [0] * ndim
                off[d] = s
                taps[tuple(off)] = w
    return StencilSpec(taps=taps, name=f"star{ndim}d_r{len(weights_by_distance)}")


def box(ndim: int, weight: float | None = None) -> StencilSpec:
    """Dense (2r+1)^ndim box average — a stencil with no zero taps."""
    n = 3**ndim
    w = weight if weight is not None else 1.0 / n
    taps = {}
    for idx in np.ndindex(*(3,) * ndim):
        off = tuple(i - 1 for i in idx)
        taps[off] = w
    return StencilSpec(taps=taps, name=f"box{ndim}d")


def causal_conv1d_spec(weights: Sequence[float]) -> StencilSpec:
    """1D causal stencil: out[t] = sum_k w[k] * x[t - (K-1) + k].

    This is the depthwise causal convolution inside Mamba2 blocks (d_conv=4)
    expressed as a stencil — the integration point between the paper's
    technique and the SSM architectures (DESIGN §4).
    """
    K = len(weights)
    taps = {(-(K - 1) + k,): float(w) for k, w in enumerate(weights)}
    return StencilSpec(taps=taps, name=f"causal_conv1d_k{K}")
