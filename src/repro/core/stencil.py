"""Stencil specification — the paper's computational object.

A stencil is a fixed pattern of weighted contributions from neighbouring grid
cells (paper §2): ``out[i] = sum_k w_k * x[i + off_k]``.  The paper's running
example is the Jacobi update for Laplace's equation for diffusion:

  2D (5-point):  out[i,j]   = 0.25*(x[i-1,j] + x[i+1,j] + x[i,j-1] + x[i,j+1])
  3D (7-point):  out[i,j,k] = (1/6)*(six face neighbours)

``StencilSpec`` is dimension-agnostic: offsets are integer tuples, weights are
floats *or per-cell weight fields* (``WeightField``) for variable-coefficient
operators — the CFD/seismic workloads the wafer-scale papers target, where
``out[i] = sum_k w_k(i) * x[i + off_k]`` and each ``w_k`` is a grid-shaped
array.  Encodings (dense / conv / Pallas kernels) consume the same spec, so
every backend computes the same operator and can be cross-validated.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import jax
import numpy as np

Offset = tuple[int, ...]


def _is_concrete(values) -> bool:
    """True when ``values`` holds actual numbers (not a jax tracer)."""
    return isinstance(values, np.ndarray) or not isinstance(
        values, jax.core.Tracer)


class WeightField:
    """A per-cell weight array: hashable when concrete, traceable as a pytree.

    ``StencilSpec`` instances are used as dict keys and static jit arguments,
    so concrete fields freeze their array (read-only, float32) and hash its
    bytes lazily; equality compares the actual values, so two specs built
    from equal fields still coincide.

    ``WeightField`` is also a registered JAX pytree (the value array is the
    single leaf), so fields can live inside parameter trees, be traced
    through ``jax.jit``/``jax.grad``, and flow into plans as runtime operands
    (see ``StencilPlan.__call__(fields=...)``).  A traced field is not
    hashable — the static spec keeps concrete template values and the traced
    values travel beside it as operands, so weight updates never recompile.
    """

    __slots__ = ("_values", "_np", "_hash")

    def __init__(self, array):
        if isinstance(array, WeightField):
            array = array.values
        if getattr(array, "ndim", None) is None or isinstance(
                array, (list, tuple)):
            array = np.asarray(array, dtype=np.float32)
        if array.ndim == 0:
            raise ValueError("WeightField needs an array, not a scalar "
                             "(pass plain floats for constant taps)")
        np_arr = None
        if isinstance(array, np.ndarray):
            np_arr = np.asarray(array, dtype=np.float32).copy()
            np_arr.setflags(write=False)
            array = np_arr
        object.__setattr__(self, "_values", array)
        object.__setattr__(self, "_np", np_arr)
        object.__setattr__(self, "_hash", None)

    def __setattr__(self, name, value):
        raise AttributeError("WeightField is immutable")

    @property
    def values(self):
        """The raw value array — np.ndarray, jax array, or tracer."""
        return self._values

    @property
    def array(self) -> np.ndarray:
        """Read-only float32 ndarray view (for plan-build-time consumers)."""
        np_arr = self._np
        if np_arr is None:
            if not _is_concrete(self._values):
                raise TypeError(
                    "WeightField holds traced values — concrete arrays are "
                    "only available outside jit/grad traces; pass traced "
                    "fields as runtime operands instead")
            np_arr = np.asarray(self._values, dtype=np.float32)
            np_arr.setflags(write=False)
            object.__setattr__(self, "_np", np_arr)
        return np_arr

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(self._values.shape)

    @property
    def ndim(self) -> int:
        return self._values.ndim

    def __hash__(self):
        h = self._hash
        if h is None:
            if not _is_concrete(self._values):
                raise TypeError(
                    "a traced WeightField is not hashable — specs carrying "
                    "traced fields cannot be jit-static; keep the template "
                    "spec concrete and pass values via the fields operand")
            arr = self.array
            h = hash((arr.shape, arr.tobytes()))
            object.__setattr__(self, "_hash", h)
        return h

    def __eq__(self, other):
        if not isinstance(other, WeightField):
            return NotImplemented
        if self is other:
            return True
        if not (_is_concrete(self._values) and _is_concrete(other._values)):
            return False
        return (self.shape == other.shape
                and np.array_equal(self.array, other.array))

    def __repr__(self):
        kind = "traced" if not _is_concrete(self._values) else "concrete"
        return f"WeightField(shape={self.shape}, {kind})"


def _wf_flatten(wf: WeightField):
    return (wf.values,), None


def _wf_unflatten(aux, children):
    del aux
    wf = object.__new__(WeightField)
    object.__setattr__(wf, "_values", children[0])
    object.__setattr__(wf, "_np", None)
    object.__setattr__(wf, "_hash", None)
    return wf


jax.tree_util.register_pytree_node(WeightField, _wf_flatten, _wf_unflatten)


def _canon_weight(off: Offset, w) -> "float | WeightField":
    """Scalar-like weights become floats; array-like become WeightFields."""
    if isinstance(w, WeightField):
        return w
    if isinstance(w, (list, tuple, np.ndarray)) or (
            hasattr(w, "ndim") and getattr(w, "ndim", 0) > 0):
        return WeightField(np.asarray(w))
    try:
        return float(w)
    except (TypeError, ValueError) as e:
        raise ValueError(
            f"malformed weight for offset {off}: {w!r} is neither a scalar "
            f"nor an array-like per-cell weight field") from e


@dataclasses.dataclass(frozen=True)
class StencilSpec:
    """A fixed neighbourhood-weight pattern.

    Attributes:
      taps: tuple of (offset, weight) pairs — offset is an integer tuple (one
        entry per grid dim), weight the contribution of that neighbour: a
        float for constant-coefficient taps or a grid-shaped array
        (``WeightField``) for spatially-varying taps.  A Mapping may be
        passed at construction; it is canonicalized to a tuple sorted by
        offset so the spec is hashable (jit-static).
      name: for reporting.
    """

    taps: tuple[tuple[Offset, "float | WeightField"], ...]
    name: str = "stencil"

    def __post_init__(self):
        taps = self.taps
        if isinstance(taps, Mapping):
            pairs = taps.items()
        else:
            pairs = taps
        canon = []
        for o, w in pairs:
            off = tuple(int(c) for c in o)
            canon.append((off, _canon_weight(off, w)))
        taps = tuple(sorted(canon, key=lambda t: t[0]))
        object.__setattr__(self, "taps", taps)
        if not self.taps:
            raise ValueError(f"{self.name}: a stencil needs at least one tap")
        ndims = {len(o) for o, _ in self.taps}
        if len(ndims) != 1:
            raise ValueError(f"inconsistent offset ranks in {self.name}: {ndims}")
        nd = next(iter(ndims))
        shapes = {w.shape for _, w in self.taps if isinstance(w, WeightField)}
        for off, w in self.taps:
            if isinstance(w, WeightField) and w.ndim != nd:
                raise ValueError(
                    f"{self.name}: weight field for offset {off} has rank "
                    f"{w.ndim} (shape {w.shape}) but the stencil is {nd}D — "
                    f"per-cell fields must be grid-shaped")
        if len(shapes) > 1:
            raise ValueError(
                f"{self.name}: weight fields disagree on the grid shape: "
                f"{sorted(shapes)} — every per-cell field must cover the "
                f"same grid")

    @property
    def ndim(self) -> int:
        return len(self.taps[0][0])

    @property
    def is_variable(self) -> bool:
        """Whether any tap carries a per-cell weight field."""
        return any(isinstance(w, WeightField) for _, w in self.taps)

    @property
    def num_variable_taps(self) -> int:
        return sum(1 for _, w in self.taps if isinstance(w, WeightField))

    @property
    def weights_shape(self) -> tuple[int, ...] | None:
        """The grid shape the weight fields cover; None for all-scalar specs."""
        for _, w in self.taps:
            if isinstance(w, WeightField):
                return w.shape
        return None

    @property
    def radius(self) -> int:
        """Max Chebyshev distance of any tap — the halo depth one application needs."""
        return max(max(abs(c) for c in off) for off, _ in self.taps)

    @property
    def footprint(self) -> tuple[int, ...]:
        """Bounding-box shape of the kernel window (2r+1 per dim for symmetric taps)."""
        lo = [min(off[d] for off, _ in self.taps) for d in range(self.ndim)]
        hi = [max(off[d] for off, _ in self.taps) for d in range(self.ndim)]
        return tuple(h - l + 1 for l, h in zip(lo, hi))

    @property
    def useful_flops_per_point(self) -> int:
        """FLOPs that contribute to the result: one mul per tap + (taps-1) adds.

        For 2D Laplace (4 taps) this is 7 = 4 mul + 3 add, matching §4 of the
        paper ("7 useful calculations ... four multiplications and three
        additions").
        """
        n = len(self.taps)
        return 2 * n - 1

    def delivered_flops_per_point_conv(self) -> int:
        """FLOPs the *conv encoding* performs per output element.

        The conv kernel covers the full footprint including zero taps: one mul
        per window element + (window-1) adds.  For the 3×3 2D Laplace window
        this is 17, matching §4 of the paper.
        """
        w = int(np.prod(self.footprint))
        return 2 * w - 1

    def delivered_flops_per_point_dense(self, n_total: int) -> int:
        """FLOPs the *dense encoding* performs per output element: (2N-1).

        With X=Y=64 ⇒ N=4096 this is 8191, matching §4 of the paper.
        """
        return 2 * n_total - 1

    def to_kernel(self, dtype=np.float32) -> np.ndarray:
        """Materialize the footprint window as a dense array (the conv kernel).

        Figure 2 of the paper: for 2D Laplace this is the 3×3 array with 0.25
        on the four faces and zeros elsewhere.
        """
        if self.is_variable:
            raise ValueError(
                f"{self.name}: a variable-coefficient spec has no single "
                f"conv kernel — its taps carry per-cell weight fields; use "
                f"the dense/gather encodings or iterate the taps directly")
        lo = [min(off[d] for off, _ in self.taps) for d in range(self.ndim)]
        ker = np.zeros(self.footprint, dtype=dtype)
        for off, w in self.taps:
            idx = tuple(o - l for o, l in zip(off, lo))
            ker[idx] = w
        return ker

    @property
    def variable_offsets(self) -> tuple[Offset, ...]:
        """Offsets of the per-cell taps, in canonical tap order."""
        return tuple(o for o, w in self.taps if isinstance(w, WeightField))

    def field_values(self) -> tuple:
        """Raw value arrays of the per-cell taps, in canonical tap order."""
        return tuple(w.values for _, w in self.taps if isinstance(w, WeightField))

    def field_stack(self):
        """The per-cell taps stacked tap-major: shape (V, *grid); None if none.

        This is the runtime-operand layout every backend streams — pass an
        array of this shape as ``fields=`` to a plan / solver to override the
        spec's baked values (e.g. with traced parameters during training).
        """
        vals = self.field_values()
        if not vals:
            return None
        if all(isinstance(v, np.ndarray) for v in vals):
            return np.stack(vals)
        import jax.numpy as jnp
        return jnp.stack([jnp.asarray(v) for v in vals])

    def with_field_values(self, values, name: str | None = None) -> "StencilSpec":
        """A spec whose per-cell taps take their values from ``values``.

        ``values`` is a (V, *grid) stack or a sequence of V grid-shaped
        arrays, matched to the variable taps in canonical tap order.  Values
        may be traced (jax arrays inside jit/grad) — the resulting spec is
        then *not* hashable and must not be used as a jit-static argument;
        it exists for trace-time consumers like ``apply_stencil``.
        """
        offs = self.variable_offsets
        if len(values) != len(offs):
            raise ValueError(
                f"{self.name}: got {len(values)} field value arrays for "
                f"{len(offs)} variable taps")
        repl = {off: WeightField(v) for off, v in zip(offs, values)}
        taps = tuple((o, repl.get(o, w)) for o, w in self.taps)
        return StencilSpec(taps=taps, name=name or self.name)


def laplace_jacobi(ndim: int) -> StencilSpec:
    """The paper's benchmark stencil: Jacobi iteration for Laplace's equation."""
    w = 1.0 / (2 * ndim)
    taps = {}
    for d in range(ndim):
        for s in (-1, 1):
            off = [0] * ndim
            off[d] = s
            taps[tuple(off)] = w
    return StencilSpec(taps=taps, name=f"laplace{ndim}d")


def star(ndim: int, weights_by_distance: Sequence[float], center: float = 0.0) -> StencilSpec:
    """Star stencil of arbitrary radius (e.g. higher-order finite differences)."""
    taps = {}
    if center != 0.0:
        taps[(0,) * ndim] = center
    for r, w in enumerate(weights_by_distance, start=1):
        if w == 0.0:
            continue
        for d in range(ndim):
            for s in (-r, r):
                off = [0] * ndim
                off[d] = s
                taps[tuple(off)] = w
    return StencilSpec(taps=taps, name=f"star{ndim}d_r{len(weights_by_distance)}")


def box(ndim: int, weight: float | None = None) -> StencilSpec:
    """Dense (2r+1)^ndim box average — a stencil with no zero taps."""
    n = 3**ndim
    w = weight if weight is not None else 1.0 / n
    taps = {}
    for idx in np.ndindex(*(3,) * ndim):
        off = tuple(i - 1 for i in idx)
        taps[off] = w
    return StencilSpec(taps=taps, name=f"box{ndim}d")


def variable_coefficient(
    base: StencilSpec, fields: Mapping[Offset, "np.ndarray"],
    name: str | None = None,
) -> StencilSpec:
    """Replace chosen taps of ``base`` with per-cell weight fields.

    ``fields`` maps offsets (which may be new or already present in ``base``)
    to grid-shaped arrays; the remaining taps keep their scalar weights.
    """
    taps: dict = dict(base.taps)
    for off, f in fields.items():
        taps[tuple(int(c) for c in off)] = WeightField(np.asarray(f))
    return StencilSpec(taps=taps, name=name or f"{base.name}_var")


def heterogeneous_jacobi(kappa, name: str | None = None) -> StencilSpec:
    """Variable-coefficient Jacobi step for heterogeneous diffusion.

    ``kappa`` is a positive per-cell conductivity field of any rank; the
    returned spec averages the face neighbours with harmonic-mean face
    conductivities, normalized per cell so the weights sum to 1 — the Jacobi
    relaxation of ``div(kappa grad u) = 0`` on a unit grid.  With constant
    ``kappa`` this reduces exactly to :func:`laplace_jacobi`.
    """
    kappa = np.asarray(kappa, dtype=np.float64)
    if kappa.ndim == 0:
        raise ValueError("heterogeneous_jacobi needs a per-cell kappa field")
    if not np.all(kappa > 0):
        raise ValueError("kappa must be positive everywhere")
    ndim = kappa.ndim
    faces: dict[Offset, np.ndarray] = {}
    for d in range(ndim):
        n = kappa.shape[d]
        for s in (-1, 1):
            # neighbour kappa with edge replication (the edge faces are under
            # the Dirichlet shell anyway, so their weights never matter)
            idx = np.clip(np.arange(n) + s, 0, n - 1)
            nbr = np.take(kappa, idx, axis=d)
            off = [0] * ndim
            off[d] = s
            faces[tuple(off)] = 2.0 * kappa * nbr / (kappa + nbr)
    total = sum(faces.values())
    taps = {off: w / total for off, w in faces.items()}
    return StencilSpec(taps=taps, name=name or f"hetero{ndim}d")


def causal_conv1d_spec(weights: Sequence[float]) -> StencilSpec:
    """1D causal stencil: out[t] = sum_k w[k] * x[t - (K-1) + k].

    This is the depthwise causal convolution inside Mamba2 blocks (d_conv=4)
    expressed as a stencil — the integration point between the paper's
    technique and the SSM architectures (DESIGN §4).
    """
    K = len(weights)
    taps = {(-(K - 1) + k,): float(w) for k, w in enumerate(weights)}
    return StencilSpec(taps=taps, name=f"causal_conv1d_k{K}")
