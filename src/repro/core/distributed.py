"""Distributed Jacobi: the paper's wafer-fabric decomposition on a TPU mesh.

The CS-1 compiler placed the grid across PEs with neighbour routing; here the
grid shards as P(row_axis, col_axis) over the device mesh and each iteration
exchanges radius-r halos (parallel/halo.py) before a *local* stencil
application — communication O(perimeter), compute O(area), the classic HPC
decomposition the WSE performs in hardware.

The per-step batch dimension (the paper's "steps", problem = N × steps) is
embarrassingly parallel and rides the pod axis in the multi-pod mesh.

The local compute is the same shifted-add stencil as the oracle; on TPU
hardware the Pallas stencil2d kernel slots in per tile (kernels/stencil2d).
Interior compute overlaps the halo permutes when the XLA latency-hiding
scheduler finds the slack — the edge-split in `_local_step` keeps the
dependency graph permute-free for the interior.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.boundary import DirichletBC
from repro.core.stencil import StencilSpec
from repro.parallel.halo import exchange_halo_2d, shard_map_compat


def _local_step(xp, spec, r, bc_value, grows, gcols, H, W):
    """One Jacobi step on a halo-augmented local tile xp (..., h+2r, w+2r)."""
    acc = None
    h, w = xp.shape[-2] - 2 * r, xp.shape[-1] - 2 * r
    for off, wgt in spec.taps:
        sl = xp[..., r + off[0]: r + off[0] + h, r + off[1]: r + off[1] + w]
        term = sl.astype(jnp.float32) * np.float32(wgt)
        acc = term if acc is None else acc + term
    interior = ((grows >= 1) & (grows < H - 1) & (gcols >= 1) & (gcols < W - 1))
    return jnp.where(interior, acc, np.float32(bc_value)).astype(xp.dtype)


def make_halo_runner(mesh, spec: StencilSpec, *, H: int, W: int,
                     bc_value: float, iterations: int,
                     row_axis: str = "data", col_axis: str = "model",
                     batch_axis: str | None = None):
    """Builds an unjitted (batch, H, W) -> (batch, H, W) halo-exchange stepper.

    The input/output are sharded P(batch_axis, row_axis, col_axis).  This is
    the distribution primitive the ``halo`` backend of ``core.plan.make_plan``
    wraps (and jits); user-facing entry points are
    ``stencil_apply(..., backend="halo", mesh=...)`` for a fixed step count
    and ``core.solver.solve(..., backend="halo", mesh=...)`` for a full
    run-to-convergence time loop.
    """
    if spec.ndim != 2:
        raise ValueError("distributed jacobi is 2D (the paper's fig-5 path)")
    r = spec.radius
    n_row = mesh.shape[row_axis]
    n_col = mesh.shape[col_axis]
    if H % n_row or W % n_col:
        raise ValueError(f"grid {H}x{W} must tile over {n_row}x{n_col}")
    h_loc, w_loc = H // n_row, W // n_col

    def local_fn(x_local):
        # x_local: (b_loc, h_loc, w_loc)
        ri = jax.lax.axis_index(row_axis)
        ci = jax.lax.axis_index(col_axis)
        grows = ri * h_loc + jnp.arange(h_loc)[:, None]
        gcols = ci * w_loc + jnp.arange(w_loc)[None, :]

        def body(x, _):
            xp = exchange_halo_2d(x, row_axis, col_axis, n_row, n_col, r)
            y = _local_step(xp, spec, r, bc_value, grows, gcols, H, W)
            return y, None

        y, _ = jax.lax.scan(body, x_local, None, length=iterations)
        return y

    in_spec = P(batch_axis, row_axis, col_axis)
    fn = shard_map_compat(local_fn, mesh, (in_spec,), in_spec)

    def run(x0):
        bc = DirichletBC(bc_value)
        x0 = jax.vmap(bc.set_boundary)(x0)
        x0 = jax.lax.with_sharding_constraint(
            x0, NamedSharding(mesh, in_spec))
        return fn(x0)

    return run
