"""Distributed Jacobi: the paper's wafer-fabric decomposition on a TPU mesh.

The CS-1 compiler placed the grid across PEs with neighbour routing; here the
grid shards as P(row_axis, col_axis) over the device mesh and each exchange
gathers radius-``r*fuse`` halos (parallel/halo.py) before ``fuse`` *local*
stencil iterations — communication O(perimeter), compute O(area), the classic
HPC decomposition the WSE performs in hardware.

Two communication-avoiding tricks from the wafer-scale scaling papers
(Rocki et al. 2010.03660; Jacquelin et al. 2204.03775):

* **Deep-halo temporal fusion** (``fuse=k``): one ``r*k``-deep exchange buys
  ``k`` local iterations.  The valid region of the halo-augmented tile
  shrinks by ``r`` per local step (the trapezoid), so the chunk runs
  ``iterations/k`` exchanges — ``k``x fewer ``ppermute`` rounds — at the
  price of recomputing the shrinking rim (priced by
  ``kernels/tiling.py::halo_fuse_redundancy``).

* **Interior/rim split with overlap**: the step consuming the exchange
  computes the tile *interior* (no halo dependency) directly from the local
  tile, before the permutes' results are consumed; only the rim strips read
  the augmented tile.  Interior result and incoming halos land in separate
  buffers combined at the end (double-buffered), so the decomposition is
  explicit in the dependency graph and XLA's latency-hiding scheduler can
  overlap the collective with the interior compute instead of being left to
  find slack in a monolithic update.

The per-step batch dimension (the paper's "steps", problem = N x steps) is
embarrassingly parallel and rides the pod axis in the multi-pod mesh.

Variable-coefficient specs shard their per-cell ``WeightField`` taps with
the grid: the stacked fields are exchanged *once per chunk* (they are
iteration-invariant) at the depth the fused output margins need, then every
local step slices the cell-aligned weights out of the augmented field tile.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.boundary import DirichletBC
from repro.core.stencil import StencilSpec, WeightField
from repro.parallel.halo import exchange_halo_2d, shard_map_compat

# One exchange_halo_2d call = two directions x two mesh axes.
HALO_PHASES_PER_EXCHANGE = 4


def halo_comm_rounds(iterations: int, fuse: int = 1, *,
                     variable: bool = False) -> int:
    """``ppermute`` rounds a chunk of ``iterations`` executes at depth
    ``fuse`` — the quantity deep-halo fusion divides by ``fuse``.  Variable
    specs pay one extra exchange for the weight fields per chunk."""
    rounds = HALO_PHASES_PER_EXCHANGE * -(-iterations // fuse)
    if variable:
        rounds += HALO_PHASES_PER_EXCHANGE
    return rounds


def max_halo_fuse(radius: int, h_loc: int, w_loc: int) -> int:
    """Deepest legal fuse on a (h_loc, w_loc) tile: one exchange phase only
    reaches the adjacent shard, so the halo depth ``radius*fuse`` cannot
    exceed the local extent."""
    return max(1, min(h_loc, w_loc) // max(radius, 1))


def _stencil_acc(xb, spec: StencilSpec, r: int, fields):
    """Raw shifted-add stencil: (..., oh+2r, ow+2r) -> (..., oh, ow) in f32.

    ``fields`` is the output-aligned stack of per-cell weights for the
    spec's variable taps, (n_var, oh, ow), or None for all-scalar specs.
    """
    oh, ow = xb.shape[-2] - 2 * r, xb.shape[-1] - 2 * r
    acc = None
    ti = 0
    for off, wgt in spec.taps:
        sl = xb[..., r + off[0]: r + off[0] + oh,
                r + off[1]: r + off[1] + ow].astype(jnp.float32)
        if isinstance(wgt, WeightField):
            term = sl * fields[ti]
            ti += 1
        else:
            term = sl * np.float32(wgt)
        acc = term if acc is None else acc + term
    return acc


def _mask_zones(acc, bc_value, grows, gcols, H, W, dtype):
    """Dirichlet semantics over the (possibly domain-exceeding) region:
    interior cells keep the stencil result, the domain shell is pinned to
    ``bc_value``, cells outside the global grid are zero — exactly the
    oracle's zero-padding, so fused rim cells iterate to the same values a
    single-device solve produces."""
    interior = ((grows >= 1) & (grows < H - 1)
                & (gcols >= 1) & (gcols < W - 1))
    in_domain = (grows >= 0) & (grows < H) & (gcols >= 0) & (gcols < W)
    shell = jnp.where(in_domain, np.float32(bc_value), np.float32(0.0))
    return jnp.where(interior, acc, shell).astype(dtype)


def make_halo_runner(mesh, spec: StencilSpec, *, H: int, W: int,
                     bc_value: float, iterations: int,
                     row_axis: str = "data", col_axis: str = "model",
                     batch_axis: str | None = None, fuse: int = 1):
    """Builds an unjitted (batch, H, W) -> (batch, H, W) halo-exchange stepper.

    The input/output are sharded P(batch_axis, row_axis, col_axis).  This is
    the distribution primitive the ``halo`` backend of ``core.plan.make_plan``
    wraps (and jits); user-facing entry points are
    ``stencil_apply(..., backend="halo", mesh=...)`` for a fixed step count
    and ``core.solver.solve(..., backend="halo", mesh=...)`` for a full
    run-to-convergence time loop.

    ``fuse=k`` exchanges an ``r*k``-deep halo once per ``k`` local
    iterations (must divide ``iterations``; depth bounded by the local tile
    extent — see :func:`max_halo_fuse`).
    """
    if spec.ndim != 2:
        raise ValueError("distributed jacobi is 2D (the paper's fig-5 path)")
    r = spec.radius
    n_row = mesh.shape[row_axis]
    n_col = mesh.shape[col_axis]
    if H % n_row or W % n_col:
        raise ValueError(f"grid {H}x{W} must tile over {n_row}x{n_col}")
    h_loc, w_loc = H // n_row, W // n_col
    if fuse < 1:
        raise ValueError(f"fuse must be >= 1, got {fuse}")
    if iterations % fuse:
        raise ValueError(
            f"iterations={iterations} not divisible by fuse={fuse}")
    R = r * fuse                 # exchanged halo depth
    Rf = R - r                   # field halo depth = deepest output margin
    if R > min(h_loc, w_loc):
        raise ValueError(
            f"fuse={fuse} needs a {R}-deep halo but the local tile is only "
            f"{h_loc}x{w_loc} over the {n_row}x{n_col} mesh (max fuse "
            f"{max_halo_fuse(r, h_loc, w_loc)})")
    var_fields = np.stack([w.array for _, w in spec.taps
                           if isinstance(w, WeightField)]) \
        if spec.is_variable else None
    # The interior/rim split needs a non-empty interior window; degenerate
    # tiles (extent < 2r) fall back to the monolithic rim-only update.
    split = min(h_loc, w_loc) >= 2 * r

    def local_fn(x_local, *field_args):
        # x_local: (b_loc, h_loc, w_loc)
        ri = jax.lax.axis_index(row_axis)
        ci = jax.lax.axis_index(col_axis)
        row0 = ri * h_loc
        col0 = ci * w_loc

        def coords(m):
            """Global coordinates of the margin-``m`` output region (the
            local tile extended by m on every side; m=-r is the interior)."""
            grows = row0 + jnp.arange(-m, h_loc + m)[:, None]
            gcols = col0 + jnp.arange(-m, w_loc + m)[None, :]
            return grows, gcols

        if field_args:
            f_local = field_args[0]          # (n_var, h_loc, w_loc)
            f_aug = f_local if Rf == 0 else exchange_halo_2d(
                f_local, row_axis, col_axis, n_row, n_col, Rf)
        else:
            f_local = f_aug = None

        def field_slice(m):
            """Output-aligned weight fields for the margin-``m`` region."""
            if f_aug is None:
                return None
            return f_aug[:, Rf - m: Rf + h_loc + m, Rf - m: Rf + w_loc + m]

        def update(xb, m):
            """Full margin-``m`` update from a margin-``m+r`` input block."""
            grows, gcols = coords(m)
            return _mask_zones(_stencil_acc(xb, spec, r, field_slice(m)),
                               bc_value, grows, gcols, H, W, x_local.dtype)

        def split_update(x, xp, m):
            """The exchange-consuming step, interior/rim decomposed.

            ``x`` is the plain local tile, ``xp`` the halo-augmented tile
            (margin m+r).  The interior block depends only on ``x`` — no
            ppermute in its dependency cone — so XLA can schedule it
            concurrently with the exchange; the four rim strips read ``xp``
            and the pieces are concatenated into a fresh margin-``m``
            buffer.
            """
            h, w = h_loc, w_loc
            gi, gj = coords(-r)
            interior = _mask_zones(
                _stencil_acc(x, spec, r,
                             None if f_local is None
                             else f_local[:, r:h - r, r:w - r]),
                bc_value, gi, gj, H, W, x.dtype)
            gr, gc = coords(m)

            def strip(rows, cols, out_rows, out_cols):
                # f_aug carries margin Rf == m, so its index space coincides
                # with the output's — the out ranges slice both.
                acc = _stencil_acc(
                    xp[..., rows[0]:rows[1], cols[0]:cols[1]], spec, r,
                    None if f_aug is None
                    else f_aug[:, out_rows[0]:out_rows[1],
                               out_cols[0]:out_cols[1]])
                return _mask_zones(acc, bc_value,
                                   gr[out_rows[0]:out_rows[1], :],
                                   gc[:, out_cols[0]:out_cols[1]],
                                   H, W, x.dtype)

            s = m + r  # rim strip width (in output cells)
            top = strip((0, s + 2 * r), (0, w + 2 * m + 2 * r),
                        (0, s), (0, w + 2 * m))
            bot = strip((h + m - r, h + 2 * m + 2 * r),
                        (0, w + 2 * m + 2 * r),
                        (h + m - r, h + 2 * m), (0, w + 2 * m))
            left = strip((s, h + m + r), (0, s + 2 * r),
                         (s, h + m - r), (0, s))
            right = strip((s, h + m + r),
                          (w + m - r, w + 2 * m + 2 * r),
                          (s, h + m - r), (w + m - r, w + 2 * m))
            mid = jnp.concatenate([left, interior, right], axis=-1)
            return jnp.concatenate([top, mid, bot], axis=-2)

        def body(x, _):
            xp = exchange_halo_2d(x, row_axis, col_axis, n_row, n_col, R)
            m = R - r
            y = split_update(x, xp, m) if split else update(xp, m)
            for _s in range(1, fuse):
                m -= r
                y = update(y, m)
            return y, None

        y, _ = jax.lax.scan(body, x_local, None, length=iterations // fuse)
        return y

    in_spec = P(batch_axis, row_axis, col_axis)
    field_spec = P(None, row_axis, col_axis)
    in_specs = (in_spec, field_spec) if var_fields is not None else (in_spec,)
    fn = shard_map_compat(local_fn, mesh, in_specs, in_spec)

    def run(x0):
        bc = DirichletBC(bc_value)
        x0 = jax.vmap(bc.set_boundary)(x0)
        x0 = jax.lax.with_sharding_constraint(
            x0, NamedSharding(mesh, in_spec))
        if var_fields is None:
            return fn(x0)
        f = jax.lax.with_sharding_constraint(
            jnp.asarray(var_fields), NamedSharding(mesh, field_spec))
        return fn(x0, f)

    return run
