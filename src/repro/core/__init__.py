"""The paper's primary contribution: stencil computation expressed through
tensor-program primitives (dense layers, convolutions) plus the TPU-native
re-think (direct Pallas stencils, temporal blocking, halo-exchange
distribution).  See DESIGN.md §1-2.

The single entry point is the dispatcher in ``plan.py``:
``stencil_apply(spec, x, backend="auto", ...)`` routes one ``StencilSpec``
through any backend (reference oracle, dense, conv, direct Pallas,
temporally-fused Pallas, sharded halo exchange), choosing via a small cost
model when ``backend="auto"``; ``make_plan`` prepares a reusable executor and
``backend_support`` reports which backends are legal for a given cell.  Every
backend is cross-validated against the oracle in tests/conformance/.

The time dimension lives in ``solver.py``: ``solve(spec, x0, ...)`` /
``Solver`` run the whole iteration loop to convergence as one compiled
program over any backend (batched per-instance convergence, distributed
halo-exchange stepping, roofline-selected temporal fusion); pinned down in
tests/solver/.

``multigrid.py`` composes those pieces into a geometric-multigrid V-cycle:
per-level smoothing plans, restriction/prolongation as ``StencilSpec``s, and
red-black Gauss-Seidel — ``multigrid_solve`` reaches the same fixed point as
``solve`` in a small constant number of fine-grid-equivalent sweeps.
Variable-coefficient operators (per-cell ``WeightField`` taps, e.g.
``heterogeneous_jacobi``) flow through the same spec/backend machinery.
"""
from repro.core.adjoint import (
    DIFF_BACKENDS,
    implicit_solve,
    transpose_fields,
    transpose_spec,
)
from repro.core.autotune import (
    TunedEntry,
    TunedTable,
    autotune_cell,
    default_tuned_table,
    set_default_tuned_table,
    shape_bucket,
    spec_family,
)
from repro.core.boundary import BoundaryMode, DirichletBC, runtime_bc_grids
from repro.core.conv1d import causal_conv1d, causal_conv1d_update
from repro.core.conv_encoding import (
    conv2d_kernel,
    conv3d_channels_kernel,
    conv3d_kernel,
    conv_jacobi_2d,
    conv_jacobi_3d_channels,
    conv_jacobi_3d_native,
    conv_var_jacobi,
    split_var_kernels,
)
from repro.core.dense_encoding import (
    build_dense_matrix,
    dense_jacobi,
    dense_jacobi_with_bc,
    dense_layer_bytes,
)
from repro.core.metrics import DeliveredPerf, encoding_flops_per_point
from repro.core.multigrid import (
    MGResult,
    Multigrid,
    coarse_shape,
    coarsen_spec,
    multigrid_solve,
    prolongation_spec,
    red_black_step,
    restriction_spec,
)
from repro.core.plan_cache import (
    CachedSolver,
    CacheStats,
    PlanCache,
    default_plan_cache,
    set_default_plan_cache,
)
from repro.core.plan import (
    BACKENDS,
    BackendSupport,
    StencilPlan,
    backend_support,
    choose_backend,
    make_plan,
    stencil_apply,
)
from repro.core.reference import apply_stencil, jacobi_reference, jacobi_step
from repro.core.solver import SolveResult, Solver, solve
from repro.core.stencil import (
    StencilSpec,
    WeightField,
    box,
    causal_conv1d_spec,
    heterogeneous_jacobi,
    laplace_jacobi,
    star,
    variable_coefficient,
)

__all__ = [
    "BACKENDS",
    "BackendSupport",
    "DIFF_BACKENDS",
    "BoundaryMode",
    "CacheStats",
    "CachedSolver",
    "DirichletBC",
    "MGResult",
    "Multigrid",
    "PlanCache",
    "SolveResult",
    "Solver",
    "StencilPlan",
    "StencilSpec",
    "TunedEntry",
    "TunedTable",
    "WeightField",
    "autotune_cell",
    "default_plan_cache",
    "default_tuned_table",
    "set_default_plan_cache",
    "set_default_tuned_table",
    "shape_bucket",
    "spec_family",
    "solve",
    "apply_stencil",
    "backend_support",
    "choose_backend",
    "make_plan",
    "stencil_apply",
    "box",
    "build_dense_matrix",
    "causal_conv1d",
    "causal_conv1d_spec",
    "causal_conv1d_update",
    "coarse_shape",
    "coarsen_spec",
    "conv2d_kernel",
    "conv3d_channels_kernel",
    "conv3d_kernel",
    "conv_jacobi_2d",
    "conv_jacobi_3d_channels",
    "conv_jacobi_3d_native",
    "conv_var_jacobi",
    "dense_jacobi",
    "dense_jacobi_with_bc",
    "dense_layer_bytes",
    "DeliveredPerf",
    "encoding_flops_per_point",
    "heterogeneous_jacobi",
    "implicit_solve",
    "jacobi_reference",
    "jacobi_step",
    "laplace_jacobi",
    "multigrid_solve",
    "runtime_bc_grids",
    "transpose_fields",
    "transpose_spec",
    "prolongation_spec",
    "red_black_step",
    "restriction_spec",
    "split_var_kernels",
    "star",
    "variable_coefficient",
]
