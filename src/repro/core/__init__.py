"""The paper's primary contribution: stencil computation expressed through
tensor-program primitives (dense layers, convolutions) plus the TPU-native
re-think (direct Pallas stencils, temporal blocking, halo-exchange
distribution).  See DESIGN.md §1-2.
"""
from repro.core.boundary import BoundaryMode, DirichletBC
from repro.core.conv1d import causal_conv1d, causal_conv1d_update
from repro.core.conv_encoding import (
    conv2d_kernel,
    conv3d_channels_kernel,
    conv3d_kernel,
    conv_jacobi_2d,
    conv_jacobi_3d_channels,
    conv_jacobi_3d_native,
)
from repro.core.dense_encoding import (
    build_dense_matrix,
    dense_jacobi,
    dense_jacobi_with_bc,
    dense_layer_bytes,
)
from repro.core.metrics import DeliveredPerf, encoding_flops_per_point
from repro.core.reference import apply_stencil, jacobi_reference, jacobi_step
from repro.core.stencil import (
    StencilSpec,
    box,
    causal_conv1d_spec,
    laplace_jacobi,
    star,
)

__all__ = [
    "BoundaryMode",
    "DirichletBC",
    "StencilSpec",
    "apply_stencil",
    "box",
    "build_dense_matrix",
    "causal_conv1d",
    "causal_conv1d_spec",
    "causal_conv1d_update",
    "conv2d_kernel",
    "conv3d_channels_kernel",
    "conv3d_kernel",
    "conv_jacobi_2d",
    "conv_jacobi_3d_channels",
    "conv_jacobi_3d_native",
    "dense_jacobi",
    "dense_jacobi_with_bc",
    "dense_layer_bytes",
    "DeliveredPerf",
    "encoding_flops_per_point",
    "jacobi_reference",
    "jacobi_step",
    "laplace_jacobi",
    "star",
]
