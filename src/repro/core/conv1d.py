"""Causal depthwise 1D convolution expressed through the stencil engine.

This is the integration point between the paper's technique and the SSM
architectures (mamba2-370m, zamba2-1.2b): the d_conv=4 depthwise causal conv
inside every Mamba2 block is a 1D stencil.  Per the paper's conv encoding it
is applied as a sliding window; causality = 'valid' padding with an explicit
left halo (the paper's manual-padding workaround, here legitimate since the
halo is the recurrent conv state during decode).
"""
from __future__ import annotations

import jax.numpy as jnp


def causal_conv1d(x: jnp.ndarray, weight: jnp.ndarray, bias: jnp.ndarray | None = None) -> jnp.ndarray:
    """x: (batch, seq, channels); weight: (K, channels) depthwise taps.

    out[b, t, c] = sum_k w[k, c] * x[b, t - (K-1) + k, c]   (zero left-pad)
    """
    K = weight.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    # Shifted-add form (the stencil engine's direct application): K shifted
    # views, weighted and summed — identical math to a depthwise conv but
    # maps to fused adds rather than an im2col matmul.
    out = jnp.zeros_like(x, dtype=jnp.float32)
    seq = x.shape[1]
    for k in range(K):
        out = out + pad[:, k : k + seq, :].astype(jnp.float32) * weight[k].astype(jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def causal_conv1d_update(
    state: jnp.ndarray, x_t: jnp.ndarray, weight: jnp.ndarray, bias: jnp.ndarray | None = None
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token decode step.

    state: (batch, K-1, channels) — the left halo (last K-1 inputs).
    x_t:   (batch, channels) — the new input.
    Returns (new_state, out_t).
    """
    K = weight.shape[0]
    window = jnp.concatenate([state, x_t[:, None, :]], axis=1)  # (B, K, C)
    out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), weight.astype(jnp.float32))
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    new_state = window[:, 1:, :]
    return new_state.astype(state.dtype), out.astype(x_t.dtype)
