"""Unified stencil dispatch — one spec, every encoding, one entry point.

``stencil.py`` promises that "every backend computes the same operator and can
be cross-validated"; this module is where that promise becomes an API.  A
``StencilSpec`` + grid shape + boundary condition can be lowered through any
of the repo's executable encodings:

  reference     pure-jnp shifted-add oracle           (core/reference.py)
  dense         N×N dense-layer matmul, BCs in-matrix (core/dense_encoding.py)
  conv          conv layer; 3D rides Conv2D channels  (core/conv_encoding.py)
  conv3d_native true Conv3D (what the CS-1 lacked)    (core/conv_encoding.py)
  pallas        direct Pallas stencil kernel          (kernels/stencil{2,3}d.py)
  pallas_fused  temporally-blocked Pallas kernel      (kernels/jacobi_fused.py)
  halo          shard_map halo-exchange distribution  (parallel/halo.py)

``backend="auto"`` picks via a small analytic cost model: per-point FLOPs for
the encoding (core/metrics.py), bytes streamed per iteration, the device
kind's vector/matmul throughput and memory bandwidth, and the arithmetic-
intensity boost temporal fusion buys.  ``backend_support`` answers *which
backends are legal* for a given (spec, grid, boundary mode, device) cell —
the conformance matrix in tests/conformance/ walks every cell and either
cross-validates it against the oracle or records the reason it is skipped.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.boundary import BoundaryMode, DirichletBC, runtime_bc_grids
from repro.core.metrics import encoding_flops_per_point
from repro.core.reference import apply_stencil
from repro.core.stencil import StencilSpec

BACKENDS = (
    "reference",
    "dense",
    "conv",
    "conv3d_native",
    "pallas",
    "pallas_fused",
    "halo",
)


# ---------------------------------------------------------------------------
# Support matrix
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BackendSupport:
    """Whether a backend can execute a cell, and if not, why not."""

    ok: bool
    reason: str = ""

    def __bool__(self) -> bool:
        return self.ok


def _no(reason: str) -> BackendSupport:
    return BackendSupport(False, reason)


_OK = BackendSupport(True)


def backend_support(
    backend: str,
    spec: StencilSpec,
    *,
    grid_shape: tuple[int, ...] | None = None,
    mode: BoundaryMode = BoundaryMode.MASK,
    bc: DirichletBC | float | None = 0.0,
    mesh=None,
) -> BackendSupport:
    """Is ``backend`` legal for this (spec, grid, mode, bc) cell?

    Returns a BackendSupport whose ``reason`` string is suitable for a test
    skip message — the conformance matrix relies on this being exhaustive.
    """
    if backend not in BACKENDS:
        return _no(f"unknown backend {backend!r} (known: {BACKENDS})")
    nd = spec.ndim
    raw = bc is None
    variable = spec.is_variable
    scalar_bc = raw or isinstance(bc, (int, float)) or (
        isinstance(bc, DirichletBC) and isinstance(bc.value, (int, float))
    )

    if variable and grid_shape is not None and \
            spec.weights_shape != tuple(grid_shape):
        return _no(f"spec carries {spec.weights_shape}-shaped weight fields "
                   f"but the grid is {tuple(grid_shape)}")

    if backend == "reference":
        return _OK  # the oracle runs everywhere; mode is a no-op for it

    if backend == "dense":
        if raw:
            return _no("dense encoding folds BCs into identity matrix rows; "
                       "raw (bc=None) zero-pad semantics not expressible")
        if mode is not BoundaryMode.MATRIX:
            return _no("dense encoding applies BCs as identity matrix rows "
                       "(BoundaryMode.MATRIX only)")
        return _OK  # per-cell fields fold into the matrix columns for free

    if backend == "conv":
        if nd == 1:
            return _no("no 1D conv encoding (use dense or reference)")
        if variable and nd == 3:
            return _no("channels-trick Conv2D shares its band weights across "
                       "the X-Y plane; per-cell weight fields not "
                       "expressible (use conv3d_native, dense, or pallas)")
        if nd == 3 and mode is not BoundaryMode.MASK:
            return _no("3D channels-trick conv supports the mask trick only")
        if raw:
            return _no("conv encoding paths bake in the Dirichlet fixup")
        if mode is BoundaryMode.MATRIX:
            return _no("MATRIX mode is the dense encoding's BC scheme")
        if variable and mode is not BoundaryMode.MASK:
            return _no("the variable-coefficient gather trick bakes in the "
                       "mask fixup (BoundaryMode.MASK only)")
        if mode is BoundaryMode.PAD and spec.radius != 1:
            return _no("BoundaryMode.PAD reconstructs the shell only for "
                       "radius-1 stencils")
        return _OK

    if backend == "conv3d_native":
        if nd != 3:
            return _no("conv3d_native is the 3D-only Conv3D path")
        if raw:
            return _no("conv encoding paths bake in the Dirichlet fixup")
        if mode is not BoundaryMode.MASK:
            return _no("conv3d_native supports the mask trick only")
        return _OK  # variable taps ride the gather trick (one-hot channels)

    if backend in ("pallas", "pallas_fused"):
        if backend == "pallas_fused" and nd != 2:
            return _no("temporal fusion kernel is 2D only (jacobi_fused.py)")
        if nd not in (2, 3):
            return _no(f"no {nd}D Pallas kernel (stencil2d/stencil3d only)")
        if not raw and mode is not BoundaryMode.MASK:
            return _no("Pallas kernels fuse the mask trick in-kernel "
                       "(BoundaryMode.MASK only)")
        if not scalar_bc:
            return _no("Pallas kernels pin the shell to a scalar bc_value; "
                       "array-valued DirichletBC unsupported")
        return _OK

    if backend == "halo":
        if nd != 2:
            return _no("halo-exchange distribution is 2D (distributed.py)")
        if raw:
            return _no("distributed jacobi bakes in the Dirichlet fixup")
        if mode is not BoundaryMode.MASK:
            return _no("distributed jacobi applies BCs via the mask trick")
        if not scalar_bc:
            return _no("distributed jacobi needs a scalar bc_value")
        tiling = _mesh_tiling(mesh)
        if tiling is None:
            return _no("halo distribution needs a mesh with >= 2 axes "
                       "(rows x cols)")
        if grid_shape is not None:
            n_row, n_col = tiling
            if grid_shape[0] % n_row or grid_shape[1] % n_col:
                return _no(f"grid {grid_shape} does not tile over the "
                           f"{n_row}x{n_col} device mesh")
        return _OK

    raise AssertionError(backend)


def _halo_fuse_legal(fuse: int, spec: StencilSpec,
                     grid_shape: tuple[int, ...], mesh) -> bool:
    """Whether a depth-``fuse`` halo schedule is executable on this cell:
    the exchanged depth ``radius*fuse`` cannot exceed the local tile extent
    (one exchange phase only reaches the adjacent shard)."""
    tiling = _mesh_tiling(mesh)
    if tiling is None:
        return False
    n_row, n_col = tiling
    if grid_shape[0] % n_row or grid_shape[1] % n_col:
        return False
    from repro.core.distributed import max_halo_fuse
    return fuse <= max_halo_fuse(spec.radius, grid_shape[0] // n_row,
                                 grid_shape[1] // n_col)


def _mesh_tiling(mesh) -> tuple[int, int] | None:
    """(n_row, n_col) of the first two mesh axes; None if the mesh can't
    host a 2D tile decomposition.  Accepts a bare (n_row, n_col) tuple so
    cost-model callers (and tuned-table validation) can price a mesh shape
    without materializing devices."""
    if mesh is None:
        return 1, 1
    if isinstance(mesh, tuple):
        return (int(mesh[0]), int(mesh[1])) if len(mesh) >= 2 else None
    names = mesh.axis_names
    if len(names) < 2:
        return None
    return mesh.shape[names[0]], mesh.shape[names[1]]


# ---------------------------------------------------------------------------
# Cost model for backend="auto"
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """Coarse per-device-kind rates the auto cost model prices against."""

    kind: str
    vector_flops: float   # elementwise / VPU FLOP/s
    matmul_flops: float   # MXU / GEMM FLOP/s
    mem_bw: float         # HBM / DRAM bytes/s
    pallas_native: bool   # False => Pallas runs in interpret mode
    collective_bw: float = 5e10  # inter-device (ICI/NVLink/net) bytes/s


DEVICE_PROFILES = {
    # One CPU core; Pallas falls back to the (slow) interpreter.
    "cpu": DeviceProfile("cpu", 5e10, 2e11, 5e10, pallas_native=False,
                         collective_bw=1e9),
    "gpu": DeviceProfile("gpu", 2e13, 1.5e14, 2e12, pallas_native=True,
                         collective_bw=1e11),
    # v5e-class: the ~240 FLOP/byte ridge the kernel docstrings cite.
    "tpu": DeviceProfile("tpu", 4e12, 2e14, 8e11, pallas_native=True,
                         collective_bw=5e10),
}

# Interpret-mode Pallas re-traces every lane op in Python — orders of
# magnitude off; the model only needs it to never win on CPU.
_INTERPRET_PENALTY = 1e4

# Fixed latency of one ppermute round (dispatch + link setup), per the four
# rounds each halo exchange runs; deep-halo fusion divides the rounds by the
# fuse depth, which is exactly what this term lets the model see.
_PERMUTE_LATENCY = 2.5e-6


def _resolve_fuse(iters: int) -> int:
    """The fuse depth pallas_fused actually runs at for ``iters`` (the same
    rule make_plan applies) — the cost model must price this, not a phantom
    deeper fusion."""
    return next((f for f in (8, 4, 2) if iters % f == 0), 1)


def estimate_seconds(
    backend: str,
    spec: StencilSpec,
    grid_shape: tuple[int, ...],
    iters: int,
    device: DeviceProfile,
    *,
    itemsize: int = 4,
    fuse: int | None = None,
    mesh_shape: tuple[int, int] | None = None,
) -> float:
    """Roofline-style time estimate for ``iters`` applications on one step.

    time = max(compute, memory) per iteration; temporal fusion divides the
    streamed bytes by the fuse depth (the whole point of jacobi_fused.py) but
    pays the trapezoid's rim recompute.  ``fuse=None`` prices the depth
    ``make_plan`` would resolve for ``iters``; passing an explicit depth lets
    callers (the solver's fuse auto-selection) compare candidate depths.

    For ``halo`` the model adds a communication term per exchange — perimeter
    bytes over ``collective_bw`` plus four ppermute latencies — divided by
    the fuse depth (deep-halo fusion's whole point), with the trapezoid rim
    recompute scaling the local compute.  ``mesh_shape`` is the (n_row,
    n_col) device tiling the perimeter is measured against; None prices a
    1x1 mesh (per-device compute unchanged, latency floor still paid).
    """
    n = int(np.prod(grid_shape))
    n_var = spec.num_variable_taps
    # Read + write the grid once per iteration; per-cell weight fields add
    # one grid-sized read per varying tap on every streaming backend.
    stream = (2 + n_var) * n * itemsize

    if backend == "dense":
        flops = encoding_flops_per_point(spec, "dense", n_total=n)
        compute = flops * n / device.matmul_flops
        # The fields are baked into the matrix, which re-streams anyway.
        mem = (n * n * itemsize + 2 * n * itemsize) / device.mem_bw
    elif backend in ("conv", "conv3d_native"):
        if spec.is_variable:
            # Gather trick: direct-form MACs for the one-hot conv plus an
            # elementwise multiply + add + reduce per varying tap.
            flops = encoding_flops_per_point(spec, "direct") + 3 * n_var
        elif spec.ndim == 3 and backend == "conv":
            flops = encoding_flops_per_point(spec, "conv3d_channels",
                                             n_total=grid_shape[0])
        else:
            flops = encoding_flops_per_point(spec, "conv")
        compute = flops * n / device.vector_flops
        mem = stream / device.mem_bw
    else:  # reference / pallas / pallas_fused / halo: direct shifted adds
        flops = encoding_flops_per_point(spec, "direct")
        compute = flops * n / device.vector_flops
        mem = stream / device.mem_bw
        if fuse is None:
            fuse = _resolve_fuse(iters) if backend == "pallas_fused" else 1
        if backend in ("pallas", "pallas_fused") and fuse > 1 and spec.ndim == 2:
            from repro.kernels.tiling import fuse_redundancy
            mem /= fuse  # fuse-depth fewer HBM round-trips ...
            # ... at the price of recomputing the overlapping block rims
            compute *= fuse_redundancy(grid_shape, fuse, spec.radius)

    if backend == "halo":
        from repro.kernels.tiling import (halo_exchange_bytes,
                                          halo_fuse_redundancy)
        n_row, n_col = mesh_shape or (1, 1)
        local = (grid_shape[0] // max(n_row, 1),
                 grid_shape[1] // max(n_col, 1))
        f = fuse if fuse and fuse > 1 else 1
        # Per-device compute: each device owns 1/(n_row*n_col) of the grid
        # but recomputes the trapezoid rim at depth f.
        shard = max(n_row * n_col, 1)
        per_iter = max(compute * halo_fuse_redundancy(local, f, spec.radius),
                       mem) / shard
        # A 1x1 mesh still dispatches the four (non-wrapping) permute rounds
        # but moves no neighbour data — latency floor only.
        wire_bytes = halo_exchange_bytes(local, f, spec.radius, itemsize) \
            if shard > 1 else 0
        comm_per_exchange = (wire_bytes / device.collective_bw
                             + 4 * _PERMUTE_LATENCY)
        return per_iter * iters + (iters / f) * comm_per_exchange

    per_iter = max(compute, mem)
    total = per_iter * iters
    if backend in ("pallas", "pallas_fused") and not device.pallas_native:
        total *= _INTERPRET_PENALTY
    return total


def choose_backend(
    spec: StencilSpec,
    grid_shape: tuple[int, ...],
    *,
    mode: BoundaryMode = BoundaryMode.MASK,
    bc: DirichletBC | float | None = 0.0,
    iters: int = 1,
    device_kind: str | None = None,
    mesh=None,
    fuse: int | None = None,
    dtype=jnp.float32,
    interpret: bool | None = None,
    tuned="default",
) -> tuple[str, dict[str, float]]:
    """Pick the cheapest supported backend; returns (name, cost table).

    Measured entries take priority over the roofline: when the tuned table
    (``tuned="default"`` → the committed ``TUNED_stencil.json``; pass a
    ``TunedTable`` to override or ``None`` to disable) holds measurements
    for this (device, family, shape-bucket, dtype) cell, the returned cost
    table contains those *measured* per-backend seconds and the pick is
    their argmin — interpret-mode measurements are structurally excluded, so
    an interpreted Pallas run can never be priced as a compiled one.  When
    no entry applies (unknown cell, stale table, unsupported backend) the
    analytic roofline below is the explicit fallback.

    Two backends are special-cased: ``halo`` is a *distribution strategy*,
    not a local encoding, so it is only considered when a mesh is explicitly
    supplied; ``reference`` is the cross-validation oracle, so auto only
    falls back to it when no real encoding supports the cell (otherwise
    "auto matches the oracle" would be circular).

    ``fuse`` prices the Pallas paths at an explicit temporal depth (e.g. the
    deepest depth the caller's chunking can actually run — the solver passes
    this); None prices the depth make_plan itself would resolve for
    ``iters``.  ``interpret=True`` declares that any Pallas plan built from
    this choice will be forced into interpret mode, so the Pallas paths are
    priced with the interpreter penalty regardless of the device profile.
    """
    if device_kind is None:
        device_kind = jax.default_backend()
    device = DEVICE_PROFILES.get(device_kind, DEVICE_PROFILES["cpu"])
    mesh_shape = _mesh_tiling(mesh) if mesh is not None else None

    # -- measured table first ---------------------------------------------
    from repro.core import autotune
    table = autotune.resolve_table(tuned)
    if table is not None and len(table):
        cell = table.lookup_cell(device_kind, autotune.spec_family(spec),
                                 tuple(grid_shape), autotune.dtype_key(dtype),
                                 mesh_shape=mesh_shape)
        measured: dict[str, float] = {}
        for e in cell:
            if e.interpreted or e.backend in measured and \
                    e.seconds(iters) >= measured[e.backend]:
                continue
            if e.backend == "halo" and mesh is None:
                continue
            if not backend_support(e.backend, spec, grid_shape=grid_shape,
                                   mode=mode, bc=bc, mesh=mesh):
                continue
            measured[e.backend] = e.seconds(iters)
        if measured:
            best = min(measured, key=measured.__getitem__)
            return best, measured

    # -- explicit roofline fallback ---------------------------------------
    costs: dict[str, float] = {}
    for b in BACKENDS:
        if b == "halo" and mesh is None:
            continue
        if b == "reference":
            continue
        if not backend_support(b, spec, grid_shape=grid_shape, mode=mode,
                               bc=bc, mesh=mesh):
            continue
        costs[b] = estimate_seconds(b, spec, grid_shape, iters, device,
                                    fuse=fuse,
                                    mesh_shape=mesh_shape if b == "halo"
                                    else None)
        if interpret is True and b in ("pallas", "pallas_fused") \
                and device.pallas_native:
            costs[b] *= _INTERPRET_PENALTY
    if not costs:
        # Oracle fallback: always legal, never preferred.
        costs["reference"] = estimate_seconds("reference", spec, grid_shape,
                                              iters, device)
    best = min(costs, key=costs.__getitem__)
    return best, costs


# ---------------------------------------------------------------------------
# Plan construction
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StencilPlan:
    """A prepared (batch, *grid) -> (batch, *grid) stencil executor.

    ``make_plan`` does the one-time work (backend choice, dense-matrix
    materialization, distributed-solver tracing) so repeated calls — the
    benchmark loops — pay only the jitted execution.

    Beyond the input field, a plan may accept *runtime operands* — traced
    arrays that change per call without recompiling, the mechanism the
    differentiable/adjoint path is built on:

      fields    (V, *grid) per-cell weight stack overriding the spec's baked
                values (canonical tap order, ``StencilSpec.field_stack``);
      source    additive interior term per iteration ((*grid) or
                (batch, *grid)) — the fixed-point form ``x <- M (S x + s) + g``;
      bc_value  Dirichlet value (scalar or full grid), possibly traced.

    ``operands`` names what this backend/mode combination supports; passing
    an unsupported operand raises at call time (Python level, not trace
    time).
    """

    spec: StencilSpec
    backend: str
    grid_shape: tuple[int, ...]
    mode: BoundaryMode
    iters: int
    fuse: int
    costs: dict[str, float]
    _fn: Callable[..., jnp.ndarray]
    # Whether the Pallas kernels behind this plan actually run interpreted
    # (False for every non-Pallas backend) — benchmarks and the autotuner
    # use this to tag rows structurally instead of trusting name suffixes.
    interpreted: bool = False
    # Where the backend choice came from: "explicit" (caller named it),
    # "tuned" (measured-table hit), or "roofline" (analytic fallback).
    source: str = "explicit"
    rim: str | None = None
    operands: frozenset = frozenset()

    def __call__(self, x: jnp.ndarray, *, fields=None, source=None,
                 bc_value=None) -> jnp.ndarray:
        for name, val in (("fields", fields), ("source", source),
                          ("bc_value", bc_value)):
            if val is not None and name not in self.operands:
                sup = ", ".join(sorted(self.operands)) or "none"
                raise ValueError(
                    f"this {self.backend!r} plan takes no runtime {name} "
                    f"operand (supported here: {sup})")
        if fields is not None:
            want = (self.spec.num_variable_taps, *self.grid_shape)
            if tuple(fields.shape) != want:
                raise ValueError(
                    f"fields operand must be shaped {want} (tap-major stack "
                    f"over the variable taps), got {tuple(fields.shape)}")
        squeeze = x.ndim == self.spec.ndim
        if squeeze:
            x = x[None]
        if x.shape[1:] != self.grid_shape:
            raise ValueError(
                f"plan built for grid {self.grid_shape}, got {x.shape[1:]}")
        out = self._fn(x, fields, source, bc_value)
        return out[0] if squeeze else out


def _as_bc(bc: DirichletBC | float | None) -> DirichletBC | None:
    if bc is None or isinstance(bc, DirichletBC):
        return bc
    return DirichletBC(float(bc))


def _scalar_bc_value(bc: DirichletBC | None) -> float | None:
    if bc is None:
        return None
    if not isinstance(bc.value, (int, float)):
        raise ValueError("this backend needs a scalar Dirichlet value")
    return float(bc.value)


def _raw_reference(x, spec, iters, fields=None):
    def one(g):
        def body(t, _):
            return apply_stencil(t, spec, fields), None
        y, _ = jax.lax.scan(body, g, None, length=iters)
        return y
    return jax.vmap(one)(x)


def _bc_reference(x, spec, bc, iters, fields=None, source=None,
                  bc_value=None, dtype=jnp.float32):
    # Same math as jacobi_reference, but the iteration loop is a lax.scan:
    # the oracle's unrolled Python loop is fine for the conformance matrix's
    # 2 iterations, but XLA compile time explodes super-linearly once the
    # solver asks for O(100)-iteration chunks.  Runtime operands ride the
    # mask-trick form directly: x <- mask * (S x + source) + bc_grid.
    grid = x.shape[1:]
    if bc_value is None:
        mask = bc.interior_mask(grid, dtype)
        bcg = bc.bc_grid(grid, dtype)
    else:
        mask, bcg = runtime_bc_grids(grid, bc_value, dtype)

    def one(g, s):
        g = g * mask + bcg
        def body(t, _):
            y = apply_stencil(t, spec, fields)
            if s is not None:
                y = y + s
            return y * mask + bcg, None
        y, _ = jax.lax.scan(body, g, None, length=iters)
        return y

    if source is None:
        return jax.vmap(lambda g: one(g, None))(x)
    src = jnp.broadcast_to(jnp.asarray(source, dtype), x.shape)
    return jax.vmap(one)(x, src)


def make_plan(
    spec: StencilSpec,
    grid_shape: tuple[int, ...],
    *,
    backend: str = "auto",
    bc: DirichletBC | float | None = 0.0,
    mode: BoundaryMode = BoundaryMode.MASK,
    iters: int = 1,
    fuse: int | None = None,
    dtype=jnp.float32,
    mesh=None,
    interpret: bool | None = None,
    device_kind: str | None = None,
    block_h: int | None = None,
    rim: str | None = None,
    tuned="default",
) -> StencilPlan:
    """Lower ``spec`` on ``grid_shape`` through one backend into a callable.

    backend="auto" routes through :func:`choose_backend` — a measured
    tuned-table entry (``tuned``) supplies the whole schedule (backend, fuse
    depth, block shape, rim strategy) when one applies; the roofline is the
    fallback.  ``bc=None`` means raw zero-padded stencil application (no
    Dirichlet fixup) — only the reference and Pallas backends can express
    it.  ``block_h``/``rim`` tune the 2D Pallas block geometry (other
    backends ignore them).
    """
    if spec.ndim != len(grid_shape):
        raise ValueError(f"spec is {spec.ndim}D but grid is {len(grid_shape)}D")
    if spec.is_variable and spec.weights_shape != tuple(grid_shape):
        raise ValueError(
            f"spec carries {spec.weights_shape}-shaped weight fields but the "
            f"grid is {tuple(grid_shape)}")
    if iters < 1:
        raise ValueError("iters must be >= 1")
    bc = _as_bc(bc)

    costs: dict[str, float] = {}
    source = "explicit"
    if backend == "auto":
        backend, costs = choose_backend(
            spec, grid_shape, mode=mode, bc=bc, iters=iters,
            device_kind=device_kind, mesh=mesh, dtype=dtype,
            interpret=interpret, tuned=tuned)
        source = "roofline"
        # A measured entry carries the whole schedule, not just the backend:
        # inherit its fuse depth / block shape / rim strategy where the
        # caller left them open.
        from repro.core import autotune
        table = autotune.resolve_table(tuned)
        entry = table.lookup(
            device_kind or jax.default_backend(), autotune.spec_family(spec),
            tuple(grid_shape), autotune.dtype_key(dtype),
            mesh_shape=_mesh_tiling(mesh) if mesh is not None else None) \
            if table else None
        if entry is not None and entry.backend == backend:
            source = "tuned"
            if fuse is None and entry.fuse > 1 and iters % entry.fuse == 0 \
                    and (backend != "halo"
                         or _halo_fuse_legal(entry.fuse, spec, grid_shape,
                                             mesh)):
                fuse = entry.fuse
            if block_h is None:
                block_h = entry.block_h
            if rim is None:
                rim = entry.rim
    sup = backend_support(backend, spec, grid_shape=grid_shape, mode=mode,
                          bc=bc, mesh=mesh)
    if not sup:
        raise ValueError(f"backend {backend!r} unsupported here: {sup.reason}")

    # ``fuse`` is a hint for the 2D Pallas paths (both scalar-bc and raw
    # execute in fuse-sized chunks) and for halo (one deep-halo exchange per
    # ``fuse`` local iterations); every other backend ignores it and the
    # plan records fuse=1 so its metadata reflects what actually runs.
    if backend == "halo":
        rim = None  # depth-vs-tile legality is make_halo_runner's check
        if fuse is None:
            fuse = 1
        elif iters % fuse:
            raise ValueError(f"iters={iters} not divisible by fuse={fuse}")
    else:
        fusing = backend == "pallas_fused" or (backend == "pallas"
                                               and spec.ndim == 2)
        if not fusing:
            fuse = 1
            rim = None
        elif fuse is None:
            if rim == "resident":
                fuse = iters  # the whole chunk stays resident in VMEM
            else:
                fuse = _resolve_fuse(iters) if backend == "pallas_fused" else 1
        elif iters % fuse:
            raise ValueError(f"iters={iters} not divisible by fuse={fuse}")
        if fusing and rim is None and fuse > 1:
            rim = "trapezoid"

    from repro.kernels.tiling import default_interpret
    interpreted = backend in ("pallas", "pallas_fused") \
        and default_interpret(interpret)

    fn, operands = _build_fn(spec, grid_shape, backend, bc, mode, iters, fuse,
                             dtype, mesh, interpret, block_h, rim)
    # One jit over the whole closure: the per-call preamble (conv-kernel
    # build, set_boundary, mask/bc grids, halo sharding constraint) traces
    # into constants, so repeated plan calls pay only compiled execution.
    # Runtime operands (fields/source/bc_value) are traced arguments; a None
    # operand is a structure change, so each used combination compiles once.
    fn = jax.jit(fn)
    return StencilPlan(spec=spec, backend=backend, grid_shape=grid_shape,
                       mode=mode, iters=iters, fuse=fuse, costs=costs, _fn=fn,
                       interpreted=interpreted, source=source, rim=rim,
                       operands=operands)


def _build_fn(spec, grid_shape, backend, bc, mode, iters, fuse, dtype, mesh,
              interpret, block_h=None, rim=None):
    """One closure per backend; all share (batch, *grid) -> same semantics.

    Returns ``(fn, operands)``: ``fn(x, fields, source, bc_value)`` and the
    frozenset of runtime-operand names this cell supports (see StencilPlan).
    """
    # Imports deferred so importing repro.core never drags in the Pallas /
    # shard_map machinery for users who only want the specs.
    var_ops = frozenset(("fields",)) if spec.is_variable else frozenset()

    if backend == "reference":
        if bc is None:
            return (lambda x, fields, source, bc_value:
                    _raw_reference(x.astype(dtype), spec, iters, fields),
                    var_ops)
        return (lambda x, fields, source, bc_value:
                _bc_reference(x.astype(dtype), spec, bc, iters, fields,
                              source, bc_value, dtype),
                var_ops | {"source", "bc_value"})

    if backend == "dense":
        from repro.core.dense_encoding import (build_dense_matrix,
                                               dense_jacobi, var_tap_indices)
        matrix = jnp.asarray(build_dense_matrix(grid_shape, spec), dtype)
        if spec.is_variable:
            matrix0 = jnp.asarray(
                build_dense_matrix(grid_shape, spec, include_variable=False),
                dtype)
            tap_k, flat_j, flat_i = var_tap_indices(grid_shape, spec)
        nvar = spec.num_variable_taps

        def run_dense(x, fields, source, bc_value):
            x = x.astype(dtype)
            if bc_value is None:
                x = jax.vmap(bc.set_boundary)(x)
                mask = bc.interior_mask(grid_shape, dtype)
            else:
                mask, bcg = runtime_bc_grids(grid_shape, bc_value, dtype)
                x = x * mask + bcg
            m = matrix
            if fields is not None:
                vals = jnp.asarray(fields, dtype).reshape(nvar, -1)
                m = matrix0.at[flat_j, flat_i].add(vals[tap_k, flat_i])
            drive = None
            if source is not None:
                s = jnp.broadcast_to(jnp.asarray(source, dtype), x.shape)
                drive = (s * mask).reshape(x.shape[0], -1)
            return dense_jacobi(x, m, iters, drive)
        return run_dense, var_ops | {"source", "bc_value"}

    if backend == "conv":
        from repro.core.conv_encoding import (conv_jacobi_2d,
                                              conv_jacobi_3d_channels,
                                              conv_var_jacobi)
        if spec.is_variable:
            return (lambda x, fields, source, bc_value:
                    conv_var_jacobi(x, spec, bc, iters, dtype=dtype,
                                    fields=fields, source=source,
                                    bc_value=bc_value),
                    frozenset(("fields", "source", "bc_value")))
        if spec.ndim == 2:
            ops = frozenset(("source", "bc_value")) \
                if mode is BoundaryMode.MASK else frozenset()
            return (lambda x, fields, source, bc_value:
                    conv_jacobi_2d(x, spec, bc, iters, mode, dtype=dtype,
                                   source=source, bc_value=bc_value), ops)
        return (lambda x, fields, source, bc_value:
                conv_jacobi_3d_channels(x, spec, bc, iters, dtype=dtype,
                                        source=source, bc_value=bc_value),
                frozenset(("source", "bc_value")))

    if backend == "conv3d_native":
        from repro.core.conv_encoding import (conv_jacobi_3d_native,
                                              conv_var_jacobi)
        if spec.is_variable:
            return (lambda x, fields, source, bc_value:
                    conv_var_jacobi(x, spec, bc, iters, dtype=dtype,
                                    fields=fields, source=source,
                                    bc_value=bc_value),
                    frozenset(("fields", "source", "bc_value")))
        return (lambda x, fields, source, bc_value:
                conv_jacobi_3d_native(x, spec, bc, iters, dtype=dtype,
                                      source=source, bc_value=bc_value),
                frozenset(("source", "bc_value")))

    if backend in ("pallas", "pallas_fused"):
        bc_value_s = _scalar_bc_value(bc)
        rim = rim or "trapezoid"
        kw2d = {"block_h": block_h} if block_h else {}
        if spec.ndim == 3:
            from repro.kernels import jacobi3d, stencil3d
            kw3d = {"block_x": block_h} if block_h else {}
            if bc_value_s is not None:
                return (lambda x, fields, source, bc_value:
                        jacobi3d(x.astype(dtype), spec, bc_value=bc_value_s,
                                 iterations=iters, interpret=interpret,
                                 **kw3d),
                        frozenset())

            def run_raw3d(x, fields, source, bc_value):
                def body(t, _):
                    return stencil3d(t, spec, interpret=interpret,
                                     **kw3d), None
                y, _ = jax.lax.scan(body, x.astype(dtype), None, length=iters)
                return y
            return run_raw3d, frozenset()

        if bc_value_s is not None:
            from repro.kernels import jacobi2d
            return (lambda x, fields, source, bc_value:
                    jacobi2d(x.astype(dtype), spec, bc_value=bc_value_s,
                             iterations=iters, fuse=fuse, interpret=interpret,
                             rim=rim, fields=fields, **kw2d),
                    var_ops)
        if spec.is_variable:
            from repro.kernels import stencil2d

            def run_raw2d_var(x, fields, source, bc_value):
                def body(t, _):
                    return stencil2d(t, spec, interpret=interpret,
                                     fields=fields, **kw2d), None
                y, _ = jax.lax.scan(body, x.astype(dtype), None, length=iters)
                return y
            return run_raw2d_var, var_ops
        from repro.kernels import jacobi2d_fused_step

        def run_raw2d(x, fields, source, bc_value):
            def body(t, _):
                return jacobi2d_fused_step(t, spec, fuse=fuse,
                                           interpret=interpret, rim=rim,
                                           **kw2d), None
            y, _ = jax.lax.scan(body, x.astype(dtype), None,
                                length=iters // fuse)
            return y
        return run_raw2d, frozenset()

    if backend == "halo":
        from repro.core.distributed import make_halo_runner
        bc_value_s = _scalar_bc_value(bc)
        if mesh is None:
            mesh = jax.make_mesh((1, 1), ("halo_row", "halo_col"))
        row_axis, col_axis = mesh.axis_names[0], mesh.axis_names[1]
        run = make_halo_runner(
            mesh, spec, H=grid_shape[0], W=grid_shape[1], bc_value=bc_value_s,
            iterations=iters, row_axis=row_axis, col_axis=col_axis, fuse=fuse)
        return (lambda x, fields, source, bc_value: run(x.astype(dtype)),
                frozenset())

    raise AssertionError(backend)


# ---------------------------------------------------------------------------
# One-shot convenience
# ---------------------------------------------------------------------------

def stencil_apply(
    spec: StencilSpec,
    x: jnp.ndarray,
    *,
    backend: str = "auto",
    bc: DirichletBC | float | None = 0.0,
    mode: BoundaryMode = BoundaryMode.MASK,
    iters: int = 1,
    fuse: int | None = None,
    mesh=None,
    interpret: bool | None = None,
    device_kind: str | None = None,
    block_h: int | None = None,
    rim: str | None = None,
    tuned="default",
) -> jnp.ndarray:
    """Apply ``iters`` stencil steps to ``x`` through any backend.

    ``x`` is (batch, *grid) or bare (*grid).  Semantics match
    ``jacobi_reference``: the Dirichlet shell is seeded, then each iteration
    applies the stencil and re-pins the shell (``bc=None`` skips both and
    iterates the raw zero-padded operator).  Every backend is cross-validated
    against the oracle in tests/conformance/.
    """
    if x.ndim not in (spec.ndim, spec.ndim + 1):
        raise ValueError(
            f"x.ndim={x.ndim} incompatible with a {spec.ndim}D spec "
            f"(expect grid or batch+grid)")
    grid_shape = tuple(x.shape[-spec.ndim:])
    plan = make_plan(spec, grid_shape, backend=backend, bc=bc, mode=mode,
                     iters=iters, fuse=fuse, dtype=x.dtype, mesh=mesh,
                     interpret=interpret, device_kind=device_kind,
                     block_h=block_h, rim=rim, tuned=tuned)
    return plan(x)
