"""Differentiable solves — the adjoint (reverse) solve as a custom VJP.

``Solver`` runs the fixed-point iteration

    x <- M (S_w x + s) + g

(M = interior mask, S_w = the stencil, s = source, g = Dirichlet shell)
inside a ``lax.while_loop``, which JAX cannot reverse-differentiate — and
unrolling thousands of iterations for autodiff would cost O(iterations)
memory anyway.  The implicit function theorem says neither is needed: at a
*converged* fixed point x*, the VJP of x* against a cotangent x̄ is itself a
stencil solve with the transposed operator,

    μ = M (S_w^T μ + x̄)          (the adjoint solve)
    λ = x̄ + S_w^T μ              (one raw transposed application)

after which every input gradient is a cheap pointwise expression:

    w̄_k   = Σ_b μ_b ⊙ shift(x*_b, off_k)     (per-cell weight fields)
    s̄     = μ   (summed over batch if the source was shared)
    v̄/ḡ  = λ ⊙ (1 − M)  (boundary value; summed to a scalar if v was)
    x̄0    = 0   (the fixed point forgets its initialisation)

The adjoint solve reuses the *same* Solver machinery — transposed spec via
tap reflection, source = x̄, bc = 0 — so the backward pass inherits the
forward's backend, convergence criteria, and batching, and memory stays O(1)
in the iteration count (only x* is saved for the backward pass).

Transposition: with (S_w x)[i] = Σ_k w_k[i] · x[i + off_k] (fields indexed
at the output cell, zero-filled reads — ``reference.apply_stencil``), the
transpose is ⟨S x, u⟩ = ⟨x, S^T u⟩ with

    (S^T u)[j] = Σ_k w_k[j − off_k] · u[j − off_k],

i.e. each tap reflects to offset −off_k and a per-cell field becomes its own
shift by −off_k (zero-filled).  Offset negation is a bijection, so the
transposed spec is again a valid ``StencilSpec``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.boundary import BoundaryMode, DirichletBC
from repro.core.reference import _shift, apply_stencil
from repro.core.stencil import StencilSpec, WeightField

# Backends whose plans take the runtime operands the VJP needs (fields /
# source / bc_value) end-to-end.  The Pallas paths bake the Dirichlet value
# into the kernel as a static scalar and take no source operand, so they can
# execute a forward solve but not host the adjoint machinery.
DIFF_BACKENDS = ("reference", "dense", "conv", "conv3d_native")


# ---------------------------------------------------------------------------
# Spec transposition
# ---------------------------------------------------------------------------

def _shift_np(a: np.ndarray, off: tuple[int, ...]) -> np.ndarray:
    """result[i] = a[i + off], zero-filled (numpy twin of reference._shift)."""
    out = np.zeros_like(a)
    src, dst = [], []
    for n, o in zip(a.shape, off):
        if abs(o) >= n:
            return out
        src.append(slice(o, n) if o >= 0 else slice(0, n + o))
        dst.append(slice(0, n - o) if o >= 0 else slice(-o, n))
    out[tuple(dst)] = a[tuple(src)]
    return out


def transpose_spec(spec: StencilSpec) -> StencilSpec:
    """The adjoint operator S^T as a StencilSpec (tap reflection).

    Scalar taps keep their weight at the negated offset; per-cell weight
    fields are shifted by the negated offset (zero-filled) so the field is
    again indexed at the *output* cell.  Transposing twice round-trips.
    """
    taps = []
    for off, w in spec.taps:
        noff = tuple(-o for o in off)
        if isinstance(w, WeightField):
            taps.append((noff, WeightField(_shift_np(w.array, noff))))
        else:
            taps.append((noff, w))
    return StencilSpec(taps=tuple(taps), name=f"{spec.name}^T")


def transpose_fields(spec: StencilSpec, fields: jnp.ndarray) -> jnp.ndarray:
    """Map a (V, *grid) runtime field stack of ``spec`` onto the canonical
    tap order of ``transpose_spec(spec)`` (traced — gradients flow through).

    ``StencilSpec`` sorts its taps canonically, so tap k of the transposed
    spec is generally *not* the reflection of tap k of ``spec``; this
    permutes accordingly.
    """
    offs = spec.variable_offsets
    shifted = {tuple(-o for o in off): _shift(fields[k], tuple(-o for o in off))
               for k, off in enumerate(offs)}
    t_offs = transpose_spec(spec).variable_offsets
    return jnp.stack([shifted[tuple(off)] for off in t_offs])


# ---------------------------------------------------------------------------
# Cached solver construction
# ---------------------------------------------------------------------------

class _Cfg(NamedTuple):
    """Hashable static settings of one differentiable solve (the
    nondiff argument of the custom_vjp)."""
    spec: StencilSpec
    grid_shape: tuple[int, ...]
    backend: str
    rtol: float | None
    atol: float | None
    norm: str
    check_every: int | None
    max_iters: int
    interpret: bool | None
    device_kind: str | None


@functools.lru_cache(maxsize=512)
def _transposed_spec(spec: StencilSpec) -> StencilSpec:
    return transpose_spec(spec)


def _solver_for(cfg: _Cfg, transposed: bool):
    # Solver construction and reuse ride the shared plan cache — one caching
    # layer with one stats/eviction policy for the whole process.  The
    # returned CachedSolver's ``run`` is trace-safe like ``Solver.run``
    # (conv/reference configs typically land on a bucketed entry, so forward
    # and adjoint solves of one family share a compiled loop).
    from repro.core.plan_cache import default_plan_cache
    spec = _transposed_spec(cfg.spec) if transposed else cfg.spec
    mode = (BoundaryMode.MATRIX if cfg.backend == "dense"
            else BoundaryMode.MASK)
    return default_plan_cache().solver(
        spec, cfg.grid_shape, backend=cfg.backend, bc=DirichletBC(0.0),
        mode=mode, rtol=cfg.rtol, atol=cfg.atol, norm=cfg.norm,
        check_every=cfg.check_every, max_iters=cfg.max_iters,
        interpret=cfg.interpret, device_kind=cfg.device_kind)


# ---------------------------------------------------------------------------
# The custom-VJP fixed point
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _solve_fp(cfg: _Cfg, fields, source, bc_value, x0):
    x, _, _, _ = _solver_for(cfg, False).run(
        x0, fields=fields, source=source, bc_value=bc_value)
    return x


def _solve_fp_fwd(cfg, fields, source, bc_value, x0):
    x = _solve_fp(cfg, fields, source, bc_value, x0)
    # O(1) residuals: the converged solution and the operands — nothing
    # proportional to the iteration count.
    return x, (fields, source, bc_value, x)


def _solve_fp_bwd(cfg, res, g):
    fields, source, bc_value, xstar = res
    spec = cfg.spec
    tspec = transpose_spec(spec)
    tfields = None if fields is None else transpose_fields(spec, fields)

    # μ = M (S^T μ + x̄): the same masked fixed-point iteration with the
    # transposed spec, source = cotangent, boundary value 0.
    g = g.astype(xstar.dtype)
    mu, _, _, _ = _solver_for(cfg, True).run(
        jnp.zeros_like(xstar), fields=tfields, source=g)
    # λ = x̄ + S^T μ (one raw transposed application; μ is zero on the shell
    # so the masked and unmasked S^T μ agree in the interior).
    lam = g + jax.vmap(lambda m: apply_stencil(m, tspec, tfields))(mu)

    m = np.zeros(cfg.grid_shape, np.float32)
    m[tuple(slice(1, -1) for _ in cfg.grid_shape)] = 1.0
    shell = jnp.asarray(1.0 - m, xstar.dtype)

    if fields is None:
        d_fields = None
    else:
        # w̄_k = Σ_b μ_b ⊙ shift(x*_b, off_k), in the *forward* spec's
        # canonical variable-tap order (the layout of the fields operand).
        d_fields = jnp.stack([
            jnp.sum(mu * jax.vmap(lambda t: _shift(t, off))(xstar), axis=0)
            for off in spec.variable_offsets
        ]).astype(fields.dtype)

    if source is None:
        d_source = None
    else:
        s = jnp.asarray(source)
        d_source = mu if s.ndim == xstar.ndim else jnp.sum(mu, axis=0)
        d_source = d_source.astype(s.dtype)

    lam_shell = lam * shell
    if jnp.ndim(bc_value) == 0:
        d_bc = jnp.sum(lam_shell)
    else:
        d_bc = jnp.sum(lam_shell, axis=0)

    return d_fields, d_source, d_bc, jnp.zeros_like(xstar)


_solve_fp.defvjp(_solve_fp_fwd, _solve_fp_bwd)


# ---------------------------------------------------------------------------
# Public entry point
# ---------------------------------------------------------------------------

def implicit_solve(
    spec: StencilSpec,
    x0: jnp.ndarray,
    *,
    fields: jnp.ndarray | None = None,
    source: jnp.ndarray | None = None,
    bc_value=0.0,
    backend: str = "auto",
    rtol: float | None = 1e-6,
    atol: float | None = 0.0,
    norm: str = "l2",
    check_every: int | None = None,
    max_iters: int = 10_000,
    interpret: bool | None = None,
    device_kind: str | None = None,
) -> jnp.ndarray:
    """Run ``spec``'s fixed point to convergence, differentiably.

    Returns the converged field (same shape as ``x0``: (batch, *grid) or
    bare).  Unlike :func:`core.solver.solve` this is a *traced, reverse-
    differentiable* function of its operands — ``jax.grad`` through it
    triggers one adjoint solve (module docstring) instead of unrolling the
    while_loop, so gradient memory is O(1) in the iteration count:

      fields    (V, *grid) per-cell weight stack for a variable spec
                (canonical tap order; ``spec.field_stack()`` for the baked
                values) — gradient: the weight-field sensitivities;
      source    additive interior term, (*grid) shared or (batch, *grid);
      bc_value  Dirichlet value, scalar or full grid;
      x0        initialisation — gradient is exactly zero (a converged
                fixed point forgets where it started).

    ``backend`` must take runtime operands (``DIFF_BACKENDS``); "auto"
    picks conv for 2D/3D, dense for small 1D grids, reference otherwise.
    ``rtol=None, atol=None`` runs exactly ``max_iters`` iterations (the
    gradient is exact for the *converged* fixed point, so run to
    convergence before trusting it).
    """
    x0 = jnp.asarray(x0)
    if x0.ndim not in (spec.ndim, spec.ndim + 1):
        raise ValueError(
            f"x0.ndim={x0.ndim} incompatible with a {spec.ndim}D spec "
            f"(expect grid or batch+grid)")
    squeeze = x0.ndim == spec.ndim
    if squeeze:
        x0 = x0[None]
    grid_shape = tuple(x0.shape[1:])

    if backend == "auto":
        if spec.ndim in (2, 3):
            backend = "conv"
        elif int(np.prod(grid_shape)) <= 64 * 64:
            backend = "dense"
        else:
            backend = "reference"
    if backend not in DIFF_BACKENDS:
        raise ValueError(
            f"backend {backend!r} cannot host a differentiable solve (its "
            f"plan lacks runtime operands); pick one of {DIFF_BACKENDS}")

    if fields is not None:
        fields = jnp.asarray(fields)
        want = (spec.num_variable_taps, *grid_shape)
        if tuple(fields.shape) != want:
            raise ValueError(
                f"fields operand must be shaped {want}, got "
                f"{tuple(fields.shape)}")

    cfg = _Cfg(spec=spec, grid_shape=grid_shape, backend=backend,
               rtol=rtol, atol=atol, norm=norm, check_every=check_every,
               max_iters=max_iters, interpret=interpret,
               device_kind=device_kind)
    x = _solve_fp(cfg, fields, source, bc_value, x0)
    return x[0] if squeeze else x
