"""mamba2-370m [ssm] — SSD, attention-free [arXiv:2405.21060; unverified].

Paper-technique carrier: the depthwise causal conv1d in every block runs
through the stencil engine (DESIGN §4).  long_500k applies (O(1) state).
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        arch="mamba2-370m", family="ssm",
        n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0, head_dim=0,
        d_ff=0, vocab_size=50280,
        ssm_state=128, d_conv=4, expand=2, ssm_head_dim=64,
        remat_group=4,
        sharding_profile="tp",
        source="[arXiv:2405.21060; unverified]",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="mamba2-370m-smoke", family="ssm",
        n_layers=3, d_model=64, n_heads=0, n_kv_heads=0, head_dim=0,
        d_ff=0, vocab_size=512,
        ssm_state=16, d_conv=4, expand=2, ssm_head_dim=32, ssm_chunk=8,
        sharding_profile="tp",
    )


register("mamba2-370m", full, smoke)
