"""Architecture configs: the ten assigned archs + the paper's Jacobi configs.

``get_config(arch_id)`` returns the exact full-size config; ``smoke=True``
returns the reduced same-family variant used by CPU smoke tests.
"""
from repro.configs.base import ModelConfig, get_config, list_archs

# Import for registration side effects.
from repro.configs import (  # noqa: F401
    glm4_9b,
    learned_stencil,
    mamba2_370m,
    moonshot_v1_16b_a3b,
    nemotron_4_15b,
    phi3_medium_14b,
    qwen2_vl_2b,
    qwen3_0_6b,
    qwen3_moe_30b_a3b,
    whisper_tiny,
    zamba2_1_2b,
)
from repro.configs.jacobi import JACOBI_CONFIGS, JacobiConfig

__all__ = ["ModelConfig", "get_config", "list_archs", "JacobiConfig",
           "JACOBI_CONFIGS"]
