"""nemotron-4-15b [dense] — GQA, squared-ReLU [arXiv:2402.16819; unverified]."""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        arch="nemotron-4-15b", family="dense",
        n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
        d_ff=24576, vocab_size=256000,
        activation="relu2", gated_mlp=False,
        rope_theta=1e4,
        remat_group=4,
        sharding_profile="tp",
        source="[arXiv:2402.16819; unverified]",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="nemotron-4-15b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512,
        activation="relu2", gated_mlp=False, q_chunk=16,
        sharding_profile="tp",
    )


register("nemotron-4-15b", full, smoke)
