"""phi3-medium-14b [dense] — RoPE SwiGLU GQA [arXiv:2404.14219; unverified].

40 heads / 10 kv heads do not divide the 16-wide model axis, so this arch
uses the sequence-parallel profile: activations seq-shard over the model
axis, weights ZeRO-shard over data (DESIGN §5, parallel/sharding.py).
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        arch="phi3-medium-14b", family="dense",
        n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10, head_dim=128,
        d_ff=17920, vocab_size=100352,
        activation="silu", gated_mlp=True,
        rope_theta=1e4,
        remat_group=4,
        sharding_profile="sp",
        source="[arXiv:2404.14219; unverified]",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="phi3-medium-14b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=5, n_kv_heads=5, head_dim=16,
        d_ff=96, vocab_size=512,
        activation="silu", gated_mlp=True, q_chunk=16,
        sharding_profile="sp",
    )


register("phi3-medium-14b", full, smoke)
