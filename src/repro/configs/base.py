"""ModelConfig — one dataclass covering all ten assigned architecture families.

Exact full-size configs live in src/repro/configs/<arch_id>.py; every arch
also exposes ``smoke()`` — a reduced same-family config for CPU tests.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch: str
    family: str                    # dense | moe | ssm | hybrid | vlm | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    activation: str = "silu"       # silu (SwiGLU) | relu2 | gelu
    gated_mlp: bool = True
    qk_norm: bool = False
    rope_theta: float = 1e4
    m_rope_sections: tuple[int, ...] | None = None
    norm_eps: float = 1e-5

    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 1024
    moe_waves: int = 16            # scan waves (memory ↔ weight-reread trade)
    moe_dispatch: str = "einsum"   # einsum (GShard one-hot) | scatter

    # SSM (Mamba2)
    ssm_state: int = 0
    d_conv: int = 4
    expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256

    # hybrid (Zamba2): a single shared attention block applied every k layers
    attn_every: int = 0

    # enc-dec (Whisper): n_layers is the decoder depth
    n_enc_layers: int = 0
    enc_len: int = 0

    # VLM (Qwen2-VL): number of stub vision-patch embeddings prepended
    n_vision_tokens: int = 0

    # execution
    attn_impl: str = "xla"         # xla | flash (Pallas kernel; TPU path)
    q_chunk: int = 1024
    remat_group: int = 1           # layers per remat span (see §Perf iter 1)
    sharding_profile: str = "tp"   # tp | sp (see parallel/sharding.py)
    source: str = ""               # provenance note [source; verified-tier]

    @property
    def padded_vocab(self) -> int:
        """Megatron-style vocab padding to a multiple of 128 so the embedding
        / lm_head / logits shard over the 16-wide model axis (50280 and 51865
        are not divisible by 16 — unpadded they replicate the logits, §Perf D
        iteration 3).  Rows beyond vocab_size are masked to -inf in the loss
        and argmax."""
        m = 128
        return (self.vocab_size + m - 1) // m * m

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_causal_lm(self) -> bool:
        return self.family in ("dense", "moe", "ssm", "hybrid", "vlm")

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        D, V = self.d_model, self.vocab_size
        emb = V * D * 2  # untied embed + lm_head
        def attn(nh=self.n_heads, nkv=self.n_kv_heads, hd=self.head_dim):
            return D * hd * (nh + 2 * nkv) + nh * hd * D
        def mlp(dff=self.d_ff, gated=self.gated_mlp):
            return D * dff * (3 if gated else 2)
        def mamba():
            di, N, H = self.d_inner, self.ssm_state, self.n_ssm_heads
            return (2 * D * di + D * 2 * N + D * H
                    + self.d_conv * (di + 2 * N) + 3 * H + di + di * D)
        if self.family in ("dense", "vlm"):
            blocks = self.n_layers * (attn() + mlp() + 2 * D)
        elif self.family == "moe":
            expert = 3 * D * self.d_ff_expert
            shared = 3 * D * self.d_ff_expert * self.n_shared_experts
            blocks = self.n_layers * (
                attn() + self.n_experts * expert + shared + D * self.n_experts + 2 * D
            )
        elif self.family == "ssm":
            blocks = self.n_layers * (mamba() + D)
        elif self.family == "hybrid":
            n_attn = self.n_layers // self.attn_every if self.attn_every else 0
            blocks = self.n_layers * (mamba() + D) + (attn() + mlp() + 2 * D)
        elif self.family == "encdec":
            enc = self.n_enc_layers * (attn() + mlp(gated=False) + 4 * D)
            dec = self.n_layers * (2 * attn() + mlp(gated=False) + 6 * D)
            blocks = enc + dec
        else:
            raise ValueError(self.family)
        return emb + blocks + D

    def active_param_count(self) -> int:
        """Active params per token (= param_count for non-MoE)."""
        if self.family != "moe":
            return self.param_count()
        expert = 3 * self.d_model * self.d_ff_expert
        inactive = self.n_layers * (self.n_experts - self.top_k) * expert
        return self.param_count() - inactive


_REGISTRY: dict[str, dict] = {}


def register(arch_id: str, full, smoke):
    _REGISTRY[arch_id] = {"full": full, "smoke": smoke}


def get_config(arch_id: str, smoke: bool = False) -> ModelConfig:
    import importlib
    if arch_id not in _REGISTRY:
        importlib.import_module(f"repro.configs.{arch_id.replace('-', '_')}")
    entry = _REGISTRY[arch_id]
    return entry["smoke" if smoke else "full"]()


def list_archs() -> list[str]:
    return [
        "nemotron-4-15b", "glm4-9b", "qwen3-0.6b", "phi3-medium-14b",
        "qwen2-vl-2b", "zamba2-1.2b", "moonshot-v1-16b-a3b",
        "qwen3-moe-30b-a3b", "whisper-tiny", "mamba2-370m",
    ]
