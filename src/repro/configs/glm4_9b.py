"""glm4-9b [dense] — RoPE, GQA kv=2 [hf:THUDM/glm-4-9b; hf]."""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        arch="glm4-9b", family="dense",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2, head_dim=128,
        d_ff=13696, vocab_size=151552,
        activation="silu", gated_mlp=True,
        rope_theta=1e4,
        remat_group=4,
        sharding_profile="tp",
        source="[hf:THUDM/glm-4-9b; hf]",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="glm4-9b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, vocab_size=512,
        activation="silu", gated_mlp=True, q_chunk=16,
        sharding_profile="tp",
    )


register("glm4-9b", full, smoke)
