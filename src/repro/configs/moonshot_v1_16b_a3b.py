"""moonshot-v1-16b-a3b [moe] — Moonlight: 64 experts top-6 + 2 shared experts
[hf:moonshotai/Moonlight-16B-A3B; hf].

Homogeneous-MoE approximation: Moonlight's first dense layer is modeled as
MoE like the rest so the layer stack scans (noted in DESIGN §4).
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        arch="moonshot-v1-16b-a3b", family="moe",
        n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
        d_ff=0, vocab_size=163840,
        activation="silu", gated_mlp=True,
        rope_theta=5e4,
        n_experts=64, top_k=6, d_ff_expert=1408, n_shared_experts=2,
        remat_group=4,
        sharding_profile="tp",
        source="[hf:moonshotai/Moonlight-16B-A3B; hf]",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="moonshot-v1-16b-a3b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=0, vocab_size=512,
        activation="silu", gated_mlp=True,
        n_experts=8, top_k=2, d_ff_expert=32, n_shared_experts=2,
        moe_group_size=64, capacity_factor=8.0, q_chunk=16,
        sharding_profile="tp",
    )


register("moonshot-v1-16b-a3b", full, smoke)
