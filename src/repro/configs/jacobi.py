"""The paper's own benchmark configurations (§4).

Table 1: 2D Jacobi, problem size 2048 M elements, X=Y=64 per step;
         dense over 7 iterations (the CS-1 layer-memory limit),
         conv over 3500 iterations.
Fig 5:   shapes {32x64, 64x64, 128x64, 128x128} at 3500 iterations.
Fig 6:   3D, X=64 Y=64 Z=10, non-zero BCs, 3500 iterations, 12 workers.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class JacobiConfig:
    name: str
    ndim: int
    grid: tuple[int, ...]          # per-step tile (X, Y) or (Z, X, Y)
    problem_elements: int          # total problem size (N * steps)
    iterations: int
    bc_value: float = 1.0
    encoding: str = "conv"         # conv | dense | conv3d_channels | direct

    @property
    def n_per_step(self) -> int:
        n = 1
        for g in self.grid:
            n *= g
        return n

    @property
    def steps(self) -> int:
        return max(1, self.problem_elements // self.n_per_step)


_2048M = 2048 * 10**6

JACOBI_CONFIGS: dict[str, JacobiConfig] = {
    # Table 1 rows (per-encoding)
    "table1-dense": JacobiConfig("table1-dense", 2, (64, 64), _2048M, 7,
                                 encoding="dense"),
    "table1-conv": JacobiConfig("table1-conv", 2, (64, 64), _2048M, 3500,
                                encoding="conv"),
    # Fig 5 shape sweep
    "fig5-32x64": JacobiConfig("fig5-32x64", 2, (32, 64), _2048M, 3500),
    "fig5-64x64": JacobiConfig("fig5-64x64", 2, (64, 64), _2048M, 3500),
    "fig5-128x64": JacobiConfig("fig5-128x64", 2, (128, 64), _2048M, 3500),
    "fig5-128x128": JacobiConfig("fig5-128x128", 2, (128, 128), _2048M, 3500),
    # Fig 6: 3D with non-zero BCs (X=64, Y=64, Z=10)
    "fig6-3d": JacobiConfig("fig6-3d", 3, (10, 64, 64), _2048M, 3500,
                            encoding="conv3d_channels"),
}
