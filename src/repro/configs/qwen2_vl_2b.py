"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

Backbone only per the assignment: the vision tower is a stub;
``input_specs()`` provides precomputed patch embeddings that occupy the first
``n_vision_tokens`` sequence positions, plus 3-channel M-RoPE position ids.
12 heads do not divide the model axis -> sequence-parallel profile.
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        arch="qwen2-vl-2b", family="vlm",
        n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
        d_ff=8960, vocab_size=151936,
        activation="silu", gated_mlp=True,
        rope_theta=1e6, m_rope_sections=(16, 24, 24),
        n_vision_tokens=1024,
        remat_group=4,
        sharding_profile="sp",
        source="[arXiv:2409.12191; hf]",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="qwen2-vl-2b-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, vocab_size=512,
        activation="silu", gated_mlp=True,
        m_rope_sections=(2, 3, 3), n_vision_tokens=8, q_chunk=16,
        sharding_profile="sp",
    )


register("qwen2-vl-2b", full, smoke)
