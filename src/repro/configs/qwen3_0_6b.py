"""qwen3-0.6b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf]."""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        arch="qwen3-0.6b", family="dense",
        n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=128,
        d_ff=3072, vocab_size=151936,
        activation="silu", gated_mlp=True, qk_norm=True,
        rope_theta=1e6,
        remat_group=4,
        sharding_profile="tp",
        source="[hf:Qwen/Qwen3-8B; hf]",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="qwen3-0.6b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, vocab_size=512,
        activation="silu", gated_mlp=True, qk_norm=True, q_chunk=16,
        sharding_profile="tp",
    )


register("qwen3-0.6b", full, smoke)
