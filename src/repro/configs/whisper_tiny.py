"""whisper-tiny [audio] — enc-dec, conv frontend stubbed [arXiv:2212.04356;
unverified].

``input_specs()`` provides precomputed frame embeddings (the conv stem is a
stub per the assignment); enc_len=1500 frames (30 s at Whisper's 2x-strided
50 Hz).  6 heads do not divide the model axis -> sequence-parallel profile.
n_layers is the decoder depth; the decoder position table is sized for the
32k decode shapes.
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        arch="whisper-tiny", family="encdec",
        n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, head_dim=64,
        d_ff=1536, vocab_size=51865,
        activation="gelu", gated_mlp=False,
        n_enc_layers=4, enc_len=1500,
        sharding_profile="sp",
        source="[arXiv:2212.04356; unverified]",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="whisper-tiny-smoke", family="encdec",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512,
        activation="gelu", gated_mlp=False,
        n_enc_layers=2, enc_len=24, q_chunk=16,
        sharding_profile="sp",
    )


register("whisper-tiny", full, smoke)
