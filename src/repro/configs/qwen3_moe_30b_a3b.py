"""qwen3-moe-30b-a3b [moe] — 128 experts top-8, qk_norm [hf:Qwen/Qwen3-30B-A3B; hf]."""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        arch="qwen3-moe-30b-a3b", family="moe",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
        d_ff=0, vocab_size=151936,
        activation="silu", gated_mlp=True, qk_norm=True,
        rope_theta=1e6,
        n_experts=128, top_k=8, d_ff_expert=768,
        remat_group=4,
        sharding_profile="tp",
        source="[hf:Qwen/Qwen3-30B-A3B; hf]",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="qwen3-moe-30b-a3b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=0, vocab_size=512,
        activation="silu", gated_mlp=True, qk_norm=True,
        n_experts=8, top_k=2, d_ff_expert=32,
        moe_group_size=64, capacity_factor=8.0, q_chunk=16,
        sharding_profile="tp",
    )


register("qwen3-moe-30b-a3b", full, smoke)
