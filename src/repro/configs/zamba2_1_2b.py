"""zamba2-1.2b [hybrid] — Mamba2 backbone + one shared attention block applied
every 6 layers [arXiv:2411.15242; hf].

38 Mamba2 layers; the shared transformer block (MHA 32 heads + SwiGLU MLP)
reuses one parameter set across its 6 applications (groups of 6 layers, with
a 2-layer tail).  This arch is a paper-technique carrier: its causal conv1d
runs through the stencil engine (DESIGN §4); long_500k applies (SSM state +
periodic attention KV).
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        arch="zamba2-1.2b", family="hybrid",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
        d_ff=8192, vocab_size=32000,
        activation="silu", gated_mlp=True,
        rope_theta=1e4,
        ssm_state=64, d_conv=4, expand=2, ssm_head_dim=64,
        attn_every=6,
        sharding_profile="tp",
        source="[arXiv:2411.15242; hf]",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="zamba2-1.2b-smoke", family="hybrid",
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512,
        activation="silu", gated_mlp=True,
        ssm_state=16, d_conv=4, expand=2, ssm_head_dim=32, ssm_chunk=8,
        attn_every=2, q_chunk=16,
        sharding_profile="tp",
    )


register("zamba2-1.2b", full, smoke)
