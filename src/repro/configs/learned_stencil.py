"""learned-stencil — the solver family's config (ISSUE 9).

Not an LM architecture: ``family="solver"`` routes ``model_zoo.build`` to
the differentiable-solve layer (models/solver_layer.py), whose parameters
are a per-cell stencil weight stack plus a scalar Dirichlet value.  It is
deliberately *not* in ``list_archs()`` — the arch-iteration tests exercise
the token-stream contract (prefill/decode), which a solver does not have.
"""
from repro.configs import base
from repro.models.solver_layer import SolverLayerConfig


def full() -> SolverLayerConfig:
    return SolverLayerConfig(
        grid=(32, 32),
        backend="conv",
        rtol=1e-5,
        max_iters=500,
    )


def smoke() -> SolverLayerConfig:
    # Small odd-ish grid, capped iterations: a train step in well under a
    # second on CPU while still converging far enough for useful gradients.
    return SolverLayerConfig(
        grid=(12, 14),
        backend="conv",
        rtol=1e-5,
        max_iters=200,
    )


base.register("learned-stencil", full, smoke)
