"""Jit'd public wrappers around the Pallas kernels.

These are the entry points the rest of the framework (examples, benchmarks,
the stencil DSL drivers) calls.  Each wrapper:
  * sets the Dirichlet shell before iterating,
  * scans the kernel over iteration chunks (``fuse`` iterations per pass for
    the temporally-blocked 2D path),
  * auto-selects interpret mode on CPU (TPU runs compiled Mosaic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.boundary import DirichletBC
from repro.core.stencil import StencilSpec
from repro.kernels.dense_stencil import dense_stencil_matmul
from repro.kernels.jacobi_fused import jacobi2d_fused_step
from repro.kernels.stencil2d import stencil2d
from repro.kernels.stencil3d import stencil3d


@functools.partial(
    jax.jit,
    static_argnames=("spec", "iterations", "fuse", "block_h", "bc_value",
                     "interpret", "rim"),
)
def jacobi2d(
    x0: jnp.ndarray,
    spec: StencilSpec,
    *,
    bc_value: float,
    iterations: int,
    fuse: int = 1,
    block_h: int = 256,
    interpret: bool | None = None,
    rim: str = "trapezoid",
    fields: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """``iterations`` Jacobi steps on (batch, H, W) via the Pallas kernels.

    fuse=1 streams one iteration per HBM round-trip (the paper-faithful
    pipeline); fuse=T applies temporal blocking (beyond-paper, §Perf) with
    ``rim`` selecting the fusion geometry (see jacobi_fused.py).
    ``iterations`` must be divisible by ``fuse``.  Variable-coefficient
    specs scan the direct ``stencil2d`` kernel at fuse=1 and the fused
    kernel (halo-replicated per-cell weight blocks) at fuse>1; ``fields``
    optionally overrides the spec's baked per-cell values with a runtime
    (V, H, W) stack (a traced operand — no recompile on value changes).
    """
    if iterations % fuse:
        raise ValueError(f"iterations={iterations} not divisible by fuse={fuse}")
    bc = DirichletBC(bc_value)
    x = jax.vmap(bc.set_boundary)(x0)

    if spec.is_variable and fuse == 1:
        def body(x, _):
            y = stencil2d(x, spec, block_h=block_h, bc_value=bc_value,
                          interpret=interpret, fields=fields)
            return y, None
    else:
        def body(x, _):
            y = jacobi2d_fused_step(
                x, spec, fuse=fuse, block_h=block_h, bc_value=bc_value,
                interpret=interpret, rim=rim, fields=fields,
            )
            return y, None

    x, _ = jax.lax.scan(body, x, None, length=iterations // fuse)
    return x


@functools.partial(
    jax.jit,
    static_argnames=("spec", "iterations", "block_x", "bc_value", "interpret"),
)
def jacobi3d(
    x0: jnp.ndarray,
    spec: StencilSpec,
    *,
    bc_value: float,
    iterations: int,
    block_x: int = 64,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """``iterations`` 3D Jacobi steps on (batch, Z, X, Y)."""
    bc = DirichletBC(bc_value)
    x = jax.vmap(bc.set_boundary)(x0)

    def body(x, _):
        y = stencil3d(x, spec, block_x=block_x, bc_value=bc_value,
                      interpret=interpret)
        return y, None

    x, _ = jax.lax.scan(body, x, None, length=iterations)
    return x


@functools.partial(
    jax.jit,
    static_argnames=("iterations", "bm", "bk", "bn", "interpret"),
)
def dense_jacobi_kernel(
    x0: jnp.ndarray,
    matrix: jnp.ndarray,
    *,
    iterations: int,
    bm: int = 128,
    bk: int = 512,
    bn: int = 512,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """The dense encoding via the Pallas blocked matmul.  x0: (batch, *grid).

    The BC lives inside ``matrix`` (identity rows); build it with
    ``core.build_dense_matrix`` and set the shell on x0 first.
    """
    batch = x0.shape[0]
    grid_shape = x0.shape[1:]
    x = x0.reshape(batch, -1)

    def body(x, _):
        y = dense_stencil_matmul(x, matrix, bm=bm, bk=bk, bn=bn,
                                 interpret=interpret)
        return y, None

    x, _ = jax.lax.scan(body, x, None, length=iterations)
    return x.reshape(batch, *grid_shape)


__all__ = [
    "dense_jacobi_kernel",
    "dense_stencil_matmul",
    "jacobi2d",
    "jacobi3d",
    "stencil2d",
    "stencil3d",
    "jacobi2d_fused_step",
]
