"""Temporally-blocked Jacobi Pallas kernel — T iterations per HBM round-trip.

The WSE's decisive advantage for stencils is that the whole grid stays in
on-chip SRAM across *all* iterations; a naive TPU conv pipeline streams the
grid HBM→VMEM→HBM every iteration, so at 7 FLOP per 8 streamed bytes it is
hopelessly memory-bound (arithmetic intensity ~0.9 vs the ~240 FLOP/byte
ridge of a v5e).  Temporal blocking is the TPU-native answer (DESIGN §2):
each VMEM tile carries a halo of depth T·r and applies the stencil T times
before writing back, multiplying arithmetic intensity by ~T at the cost of
O(T·r) redundant rim compute (the classic trapezoid/overlapped-tiling
scheme).

Correctness of the trapezoid: after iteration t, only points ≥ (T−t)·r rows
inside the block rim are valid — the final (block_h, W) centre is exactly
valid after T iterations.  In-array interior points never read out-of-array
points (the Dirichlet shell separates them), so the rim garbage never
propagates inward; the shell itself is re-pinned to the BC value every
iteration by the fused mask trick.

Two rim strategies (``rim=``, searched by the autotuner):

  "trapezoid"  the scheme above — overlapping row blocks, halo T·r deep,
               O(T·r) redundant rim recompute per block;
  "resident"   the whole grid lives in ONE VMEM block (the closest TPU
               analogue of the WSE's grid-stays-in-SRAM execution): the
               out-of-grid rim is re-zeroed between in-kernel iterations
               instead of being carried in a deeper halo, so there is no
               redundant compute and *no geometric limit on T* — depths the
               trapezoid rejects (T > block_h/r, or any T with a halo wider
               than the block) are legal.  Only valid when the padded grid
               fits VMEM (``tiling.resident_fits``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.stencil import StencilSpec, WeightField
from repro.kernels.tiling import (
    default_interpret,
    fused_block_geometry,
    halo_block_spec,
    resident_fits,
    shift2d,
)


def _kernel(x_ref, *refs, spec: StencilSpec, r: int, T: int,
            block_h: int, H: int, W: int, bc_value: float | None):
    w_ref, o_ref = (refs[0], refs[1]) if len(refs) == 2 else (None, refs[0])
    i = pl.program_id(1)
    xb = x_ref[0].astype(jnp.float32)  # (block_h + 2Tr, Wp + 2Tr)
    halo = T * r
    row0 = i * block_h - halo  # global row of xb[0, 0]
    col0 = -halo

    def coords(shape, ro, co):
        rows = ro + jax.lax.broadcasted_iota(jnp.int32, shape, 0)
        cols = co + jax.lax.broadcasted_iota(jnp.int32, shape, 1)
        return rows, cols

    rows, cols = coords(xb.shape, row0, col0)
    in_array = (rows >= 0) & (rows < H) & (cols >= 0) & (cols < W)
    xb = jnp.where(in_array, xb, 0.0)
    if bc_value is not None:
        shell = in_array & ~(
            (rows >= 1) & (rows < H - 1) & (cols >= 1) & (cols < W - 1)
        )
        xb = jnp.where(shell, np.float32(bc_value), xb)

    for t in range(T):
        acc = None
        # After this iteration the valid window shrinks by r per side: the
        # output spans rows [row0 + r, ...], i.e. offset (t+1)*r into the
        # halo-replicated per-cell weight block (which is aligned with the
        # *initial* xb).  Garbage field reads only land on out-of-array
        # output cells, which the in_array mask below zeroes.
        ah, aw = xb.shape[0] - 2 * r, xb.shape[1] - 2 * r
        o0 = (t + 1) * r
        k = 0
        for off, wgt in spec.taps:
            term = shift2d(xb, off[0], off[1], r)
            if isinstance(wgt, WeightField):
                term = term * w_ref[k, o0:o0 + ah, o0:o0 + aw].astype(
                    jnp.float32)
                k += 1
            else:
                term = term * np.float32(wgt)
            acc = term if acc is None else acc + term
        row0 += r
        col0 += r
        rows, cols = coords(acc.shape, row0, col0)
        in_array = (rows >= 0) & (rows < H) & (cols >= 0) & (cols < W)
        acc = jnp.where(in_array, acc, 0.0)
        if bc_value is not None:
            shell = in_array & ~(
                (rows >= 1) & (rows < H - 1) & (cols >= 1) & (cols < W - 1)
            )
            acc = jnp.where(shell, np.float32(bc_value), acc)
        xb = acc

    o_ref[0] = xb.astype(o_ref.dtype)


def _shift2d_zfill(xb: jnp.ndarray, dr: int, dc: int, r: int) -> jnp.ndarray:
    """result[i,j] = xb[i+dr, j+dc] with zero fill — same contract as
    ``shift2d`` but for a block with no halo (the resident strategy)."""
    h, w = xb.shape
    xp = jnp.pad(xb, ((r, r), (r, r)))
    return jax.lax.slice(xp, (r + dr, r + dc), (r + dr + h, r + dc + w))


def _resident_kernel(x_ref, *refs, spec: StencilSpec, r: int, T: int,
                     H: int, W: int, bc_value: float | None):
    """T iterations with the whole grid in VMEM; the rim is *refreshed*
    (out-of-grid zeroed, shell re-pinned) every iteration instead of being
    carried in a T·r-deep halo, so no work is redundant and T is unbounded.
    Per-cell weight fields (if any) are output-aligned full-grid blocks.
    """
    w_ref, o_ref = (refs[0], refs[1]) if len(refs) == 2 else (None, refs[0])
    xb = x_ref[0].astype(jnp.float32)  # (Hp, Wp) — the entire padded grid
    rows = jax.lax.broadcasted_iota(jnp.int32, xb.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, xb.shape, 1)
    in_array = (rows < H) & (cols < W)
    shell = in_array & ~(
        (rows >= 1) & (rows < H - 1) & (cols >= 1) & (cols < W - 1)
    )
    xb = jnp.where(in_array, xb, 0.0)
    if bc_value is not None:
        xb = jnp.where(shell, np.float32(bc_value), xb)

    for _ in range(T):
        acc = None
        k = 0
        for off, wgt in spec.taps:
            term = _shift2d_zfill(xb, off[0], off[1], r)
            if isinstance(wgt, WeightField):
                term = term * w_ref[k].astype(jnp.float32)
                k += 1
            else:
                term = term * np.float32(wgt)
            acc = term if acc is None else acc + term
        acc = jnp.where(in_array, acc, 0.0)
        if bc_value is not None:
            acc = jnp.where(shell, np.float32(bc_value), acc)
        xb = acc

    o_ref[0] = xb.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("spec", "fuse", "block_h", "bc_value", "interpret",
                     "rim"),
)
def jacobi2d_fused_step(
    x: jnp.ndarray,
    spec: StencilSpec,
    *,
    fuse: int,
    block_h: int = 256,
    bc_value: float | None = None,
    interpret: bool | None = None,
    rim: str = "trapezoid",
    fields: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """``fuse`` Jacobi iterations in one kernel pass.  x: (batch, H, W).

    Assumes the Dirichlet shell of x is already set (wrapper does this);
    with bc_value=None computes ``fuse`` raw zero-padded stencil steps.
    ``rim`` selects the fusion geometry (see module docstring); the
    "resident" strategy requires the grid to fit one VMEM block.

    Variable-coefficient specs stream their per-cell weight fields as an
    extra operand: trapezoid blocks carry the same T·r halo replication as
    x (iteration t reads the fields at static offset (t+1)·r), the resident
    strategy reads the full output-aligned grid.  ``fields`` optionally
    overrides the spec's baked values with a runtime (V, H, W) stack.
    """
    if spec.ndim != 2:
        raise ValueError("jacobi2d_fused_step needs a 2D spec")
    interpret = default_interpret(interpret)
    B, H, W = x.shape
    r = spec.radius
    bh, Hp, Wp, halo = fused_block_geometry(H, W, fuse, r, block_h, rim)
    xp = jnp.pad(x, ((0, 0), (0, Hp - H), (0, Wp - W)))

    wf = None
    if spec.is_variable:
        if fields is None:
            fields = np.stack([w.array for _, w in spec.taps
                               if isinstance(w, WeightField)])
        wf = jnp.asarray(fields, jnp.float32)
        wf = jnp.pad(wf, ((0, 0), (0, Hp - H), (0, Wp - W)))

    if rim == "resident":
        if not resident_fits((H, W), np.dtype(np.float32).itemsize):
            raise ValueError(
                f"rim='resident' needs the whole {H}x{W} grid in one VMEM "
                f"block; use rim='trapezoid' for grids this large")
        kern = functools.partial(
            _resident_kernel, spec=spec, r=r, T=fuse, H=H, W=W,
            bc_value=bc_value,
        )
        in_specs = [pl.BlockSpec((1, Hp, Wp), lambda b: (b, 0, 0))]
        operands = [xp]
        if wf is not None:
            in_specs.append(
                pl.BlockSpec((wf.shape[0], Hp, Wp), lambda b: (0, 0, 0)))
            operands.append(wf)
        out = pl.pallas_call(
            kern,
            grid=(B,),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, Hp, Wp), lambda b: (b, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((B, Hp, Wp), x.dtype),
            interpret=interpret,
        )(*operands)
        return out[:, :H, :W]

    kern = functools.partial(
        _kernel, spec=spec, r=r, T=fuse, block_h=bh, H=H, W=W, bc_value=bc_value
    )
    in_specs = [
        halo_block_spec(
            (1, bh + 2 * halo, Wp + 2 * halo),
            lambda b, i: (b, i * bh, 0),
            ((0, 0), (halo, halo), (halo, halo)),
        )
    ]
    operands = [xp]
    if wf is not None:
        # Same halo-replicated geometry as x, shared across the batch axis:
        # in-kernel iteration t slices the fields at offset (t+1)*r.
        in_specs.append(
            halo_block_spec(
                (wf.shape[0], bh + 2 * halo, Wp + 2 * halo),
                lambda b, i: (0, i * bh, 0),
                ((0, 0), (halo, halo), (halo, halo)),
            )
        )
        operands.append(wf)
    out = pl.pallas_call(
        kern,
        grid=(B, Hp // bh),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bh, Wp), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hp, Wp), x.dtype),
        interpret=interpret,
    )(*operands)
    return out[:, :H, :W]
