"""Blocked-matmul Pallas kernel for the paper's *dense-layer* encoding.

One Jacobi iteration = x(S,N) @ W(N,N) — the encoding is a plain GEMM, so
unlike the direct stencil this one *is* MXU work: (bm,bk)@(bk,bn) tiles,
fp32 VMEM accumulator, K-innermost grid with revisiting.  This kernel exists
to reproduce the paper's dense path faithfully at the kernel level and to
show on the roofline how its (2N−1)/7 redundancy dominates regardless of
how well the GEMM itself runs (EXPERIMENTS §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import round_up as _round_up, tpu_compiler_params


def _kernel(x_ref, w_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bk", "bn", "interpret")
)
def dense_stencil_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    bm: int = 128,
    bk: int = 512,
    bn: int = 512,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """x: (S, N) @ w: (N, N) -> (S, N), fp32 accumulation in VMEM scratch."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    S, N = x.shape
    if w.shape != (N, N):
        raise ValueError(f"w must be ({N},{N}), got {w.shape}")
    bm = min(bm, _round_up(S, 8))
    bk = min(bk, _round_up(N, 128))
    bn = min(bn, _round_up(N, 128))
    Sp, Kp, Np = _round_up(S, bm), _round_up(N, bk), _round_up(N, bn)
    xp = jnp.pad(x, ((0, Sp - S), (0, Kp - N)))
    wp = jnp.pad(w, ((0, Kp - N), (0, Np - N)))

    out = pl.pallas_call(
        _kernel,
        grid=(Sp // bm, Np // bn, Kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            pl.BlockSpec((bk, bn), lambda m, n, k: (k, n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((Sp, Np), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xp, wp)
    return out[:S, :N]
