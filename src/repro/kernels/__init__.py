"""Pallas TPU kernels for the paper's compute hot-spot (stencil application).

Layout per kernel: <name>.py holds the pl.pallas_call + BlockSpec tiling,
ops.py the jit'd wrappers, ref.py the pure-jnp oracles.  All kernels are
validated in interpret mode on CPU (tests/test_kernels_*) and target TPU
Mosaic when run on hardware.
"""
from repro.kernels.ops import (
    dense_jacobi_kernel,
    dense_stencil_matmul,
    jacobi2d,
    jacobi2d_fused_step,
    jacobi3d,
    stencil2d,
    stencil3d,
)

__all__ = [
    "dense_jacobi_kernel",
    "dense_stencil_matmul",
    "jacobi2d",
    "jacobi2d_fused_step",
    "jacobi3d",
    "stencil2d",
    "stencil3d",
]
