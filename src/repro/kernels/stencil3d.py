"""Direct 3D stencil Pallas kernel (7-point and general radius-r).

The paper could not express 3D natively (no Conv3D on the CS-1) and paid a
Z²-banded channel matrix instead (Figures 3-4).  On TPU we tile the X
dimension into VMEM blocks with halo (``tiling.halo_block_spec``); Z and Y stay whole in
the block (Z is small in the paper's workloads — Z=10 — and Y rides the
128-lane dim).  Z-shifts are in-block with zero fill via concatenation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.stencil import StencilSpec, WeightField
from repro.kernels.tiling import default_interpret, halo_block_spec, round_up


def _shift3d(xb: jnp.ndarray, dz: int, dx: int, dy: int, r: int) -> jnp.ndarray:
    """result[z,i,j] = xb_padded[z+dz, r+i+dx, r+j+dy], zero-filled in Z."""
    Z, h, w = xb.shape
    if dz > 0:
        xz = jnp.concatenate([xb[dz:], jnp.zeros((dz, h, w), xb.dtype)], axis=0)
    elif dz < 0:
        xz = jnp.concatenate([jnp.zeros((-dz, h, w), xb.dtype), xb[:dz]], axis=0)
    else:
        xz = xb
    return jax.lax.slice(xz, (0, r + dx, r + dy), (Z, h - r + dx, w - r + dy))


def _kernel(x_ref, *refs, spec: StencilSpec, r: int, block_x: int,
            Z: int, X: int, Y: int, bc_value: float | None):
    w_ref, o_ref = (refs[0], refs[1]) if len(refs) == 2 else (None, refs[0])
    i = pl.program_id(1)
    xb = x_ref[0].astype(jnp.float32)  # (Z, block_x + 2r, Yp + 2r)
    _, bx2, by2 = xb.shape
    zs = jax.lax.broadcasted_iota(jnp.int32, xb.shape, 0)
    xs = i * block_x - r + jax.lax.broadcasted_iota(jnp.int32, xb.shape, 1)
    ys = -r + jax.lax.broadcasted_iota(jnp.int32, xb.shape, 2)
    in_array = (xs >= 0) & (xs < X) & (ys >= 0) & (ys < Y)
    xb = jnp.where(in_array, xb, 0.0)

    acc = None
    k = 0
    for off, wgt in spec.taps:
        term = _shift3d(xb, off[0], off[1], off[2], r)
        if isinstance(wgt, WeightField):
            term = term * w_ref[k].astype(jnp.float32)
            k += 1
        else:
            term = term * np.float32(wgt)
        acc = term if acc is None else acc + term

    if bc_value is not None:
        ozs = zs[:, r:-r, r:-r] if r else zs
        oxs = xs[:, r:-r, r:-r] if r else xs
        oys = ys[:, r:-r, r:-r] if r else ys
        interior = (
            (ozs >= 1) & (ozs < Z - 1)
            & (oxs >= 1) & (oxs < X - 1)
            & (oys >= 1) & (oys < Y - 1)
        )
        acc = jnp.where(interior, acc, np.float32(bc_value))
    o_ref[0] = acc.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("spec", "block_x", "bc_value", "interpret"),
)
def stencil3d(
    x: jnp.ndarray,
    spec: StencilSpec,
    *,
    block_x: int = 64,
    bc_value: float | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """One 3D stencil step.  x: (batch, Z, X, Y).

    bc_value=None → raw zero-padded stencil (matches stencil3d_ref);
    bc_value=v    → fused Jacobi step with scalar Dirichlet BC.
    """
    if spec.ndim != 3:
        raise ValueError("stencil3d needs a 3D spec")
    interpret = default_interpret(interpret)
    B, Z, X, Y = x.shape
    r = spec.radius
    bx = min(block_x, round_up(X, 8))
    Xp = round_up(X, bx)
    Yp = round_up(Y, 128)
    xp = jnp.pad(x, ((0, 0), (0, 0), (0, Xp - X), (0, Yp - Y)))

    kern = functools.partial(
        _kernel, spec=spec, r=r, block_x=bx, Z=Z, X=X, Y=Y, bc_value=bc_value
    )
    in_specs = [
        halo_block_spec(
            (1, Z, bx + 2 * r, Yp + 2 * r),
            lambda b, i: (b, 0, i * bx, 0),
            ((0, 0), (0, 0), (r, r), (r, r)),
        )
    ]
    operands = [xp]
    if spec.is_variable:
        # Per-cell weight fields: output-aligned X blocks, batch-shared.
        fields = np.stack([w.array for _, w in spec.taps
                           if isinstance(w, WeightField)])
        wf = jnp.asarray(fields, jnp.float32)
        wf = jnp.pad(wf, ((0, 0), (0, 0), (0, Xp - X), (0, Yp - Y)))
        in_specs.append(
            pl.BlockSpec((wf.shape[0], Z, bx, Yp), lambda b, i: (0, 0, i, 0)))
        operands.append(wf)
    out = pl.pallas_call(
        kern,
        grid=(B, Xp // bx),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, Z, bx, Yp), lambda b, i: (b, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Z, Xp, Yp), x.dtype),
        interpret=interpret,
    )(*operands)
    return out[:, :, :X, :Y]
