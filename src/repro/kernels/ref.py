"""Pure-jnp oracles for every Pallas kernel in this package.

These are deliberately naive (shifted adds / plain matmul) and are the
ground truth for the per-kernel allclose sweeps in tests/test_kernels_*.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.boundary import DirichletBC
from repro.core.reference import apply_stencil
from repro.core.stencil import StencilSpec


def stencil2d_ref(x: jnp.ndarray, spec: StencilSpec) -> jnp.ndarray:
    """Raw 2D stencil, zero padding.  x: (batch, H, W)."""
    return jnp.stack([apply_stencil(x[i], spec) for i in range(x.shape[0])])


def stencil3d_ref(x: jnp.ndarray, spec: StencilSpec) -> jnp.ndarray:
    """Raw 3D stencil, zero padding.  x: (batch, Z, X, Y)."""
    return jnp.stack([apply_stencil(x[i], spec) for i in range(x.shape[0])])


def jacobi2d_ref(
    x: jnp.ndarray, spec: StencilSpec, bc_value: float, iterations: int
) -> jnp.ndarray:
    """Jacobi with scalar Dirichlet BC.  x: (batch, H, W)."""
    bc = DirichletBC(bc_value)
    out = []
    for i in range(x.shape[0]):
        g = bc.set_boundary(x[i])
        for _ in range(iterations):
            g = bc.apply_mask_trick(apply_stencil(g, spec))
        out.append(g)
    return jnp.stack(out)


def dense_stencil_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x: (S, N) @ w: (N, N) with fp32 accumulation."""
    return jnp.matmul(x, w, preferred_element_type=jnp.float32).astype(x.dtype)
