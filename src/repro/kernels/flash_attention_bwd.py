"""Flash attention backward Pallas kernels (FA-2 style, arXiv:2307.08691).

Completes §Perf C: with forward + backward kernels the (B,H,S,S) probability
tensors never touch HBM in training either.  Scheme:

  forward extras : lse row statistics (m + log l), saved with q,k,v,o
  dq kernel      : grid (B,H,iq,ik), kv innermost, accumulates dq in VMEM
  dkv kernel     : grid (B,KV,g,ik,iq), q innermost, accumulates dk/dv in
                   VMEM; the GQA group dim g folds into the accumulation
                   (no (B,S,H,hd)-sized dk materializes)

Both recompute p = exp(q·kᵀ·scale − lse) blockwise from the saved lse — the
flash trick: O(S²) recompute, O(S) storage.  ``flash_attention_grad``
assembles them into a jax.custom_vjp op validated against the XLA oracle's
gradients (tests/test_flash_attention.py::TestFlashBackward).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import round_up as _round_up, tpu_compiler_params

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# forward with lse output
# ---------------------------------------------------------------------------

def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref, acc_ref, *,
                scale, causal, bq, bk, Sq, Skv, kv_offset):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1) - kv_offset
    run = (ik * bk - kv_offset) <= (iq * bq + bq - 1) if causal else True

    @pl.when(run)
    def _block():
        q = q_ref[0, 0]
        k = k_ref[0, 0]
        v = v_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        valid = k_pos < Skv
        if causal:
            valid = valid & (k_pos <= q_pos)
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == pl.num_programs(3) - 1)
    def _flush():
        l = l_ref[...]
        l_safe = jnp.where(l == 0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0] = m_ref[...] + jnp.log(l_safe)


def _flash_fwd(q, k, v, *, causal, scale, bq, bk, kv_offset, skv_true):
    B, H, Sqp, hd = q.shape
    Skp = k.shape[2]
    G = H // k.shape[1]
    kern = functools.partial(_fwd_kernel, scale=scale, causal=causal, bq=bq,
                             bk=bk, Sq=Sqp, Skv=skv_true, kv_offset=kv_offset)
    interpret = jax.default_backend() == "cpu"
    o, lse = pl.pallas_call(
        kern,
        grid=(B, H, Sqp // bq, Skp // bk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, iq, ik: (b, h, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Sqp, hd), q.dtype),
            jax.ShapeDtypeStruct((B, H, Sqp), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return o, lse


# ---------------------------------------------------------------------------
# dq kernel: grid (B, H, iq, ik), kv innermost
# ---------------------------------------------------------------------------

def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               acc_ref, *, scale, causal, bq, bk, Skv, kv_offset):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1) - kv_offset
    run = (ik * bk - kv_offset) <= (iq * bq + bq - 1) if causal else True

    @pl.when(run)
    def _block():
        q = q_ref[0, 0]
        k = k_ref[0, 0]
        v = v_ref[0, 0]
        do = do_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        valid = k_pos < Skv
        if causal:
            valid = valid & (k_pos <= q_pos)
        s = jnp.where(valid, s, NEG_INF)
        p = jnp.exp(s - lse_ref[0, 0][:, None])                  # (bq, bk)
        dp = jax.lax.dot_general(do, v.astype(jnp.float32),
                                 (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0, 0][:, None]) * scale
        acc_ref[...] += jax.lax.dot_general(
            ds.astype(k.dtype), k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ik == pl.num_programs(3) - 1)
    def _flush():
        dq_ref[0, 0] = acc_ref[...].astype(dq_ref.dtype)


# ---------------------------------------------------------------------------
# dk/dv kernel: grid (B, KV, G, ik, iq), q innermost; dk/dv accumulate over
# both iq and the GQA group dim g
# ---------------------------------------------------------------------------

def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_acc, dv_acc, *, scale, causal, bq, bk,
                Skv, kv_offset, n_g):
    ik = pl.program_id(2)
    g = pl.program_id(3)
    iq = pl.program_id(4)

    first = (g == 0) & (iq == 0)

    @pl.when(first)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1) - kv_offset
    run = (ik * bk - kv_offset) <= (iq * bq + bq - 1) if causal else True

    @pl.when(run)
    def _block():
        q = q_ref[0, 0, 0]
        k = k_ref[0, 0]
        v = v_ref[0, 0]
        do = do_ref[0, 0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        valid = k_pos < Skv
        if causal:
            valid = valid & (k_pos <= q_pos)
        s = jnp.where(valid, s, NEG_INF)
        p = jnp.exp(s - lse_ref[0, 0, 0][:, None])               # (bq, bk)
        # dv += p^T dO
        dv_acc[...] += jax.lax.dot_general(
            p.astype(do.dtype), do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v.astype(jnp.float32),
                                 (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0, 0, 0][:, None]) * scale      # (bq, bk)
        dk_acc[...] += jax.lax.dot_general(
            ds.astype(q.dtype), q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    last = (g == n_g - 1) & (iq == pl.num_programs(4) - 1)

    @pl.when(last)
    def _flush():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


def _flash_bwd(q, k, v, o, lse, do, *, causal, scale, bq, bk, kv_offset,
               skv_true):
    """All arrays in (B, heads, seq, hd) layout (padded)."""
    B, H, Sqp, hd = q.shape
    KV, Skp = k.shape[1], k.shape[2]
    G = H // KV
    interpret = jax.default_backend() == "cpu"
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale=scale, causal=causal, bq=bq,
                          bk=bk, Skv=skv_true, kv_offset=kv_offset),
        grid=(B, H, Sqp // bq, Skp // bk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, iq, ik: (b, h, iq)),
            pl.BlockSpec((1, 1, bq), lambda b, h, iq, ik: (b, h, iq)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sqp, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, hd), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    # q reshaped to (B, KV, G, Sq, hd) so the group dim is a grid axis
    q5 = q.reshape(B, KV, G, Sqp, hd)
    do5 = do.reshape(B, KV, G, Sqp, hd)
    lse5 = lse.reshape(B, KV, G, Sqp)
    delta5 = delta.reshape(B, KV, G, Sqp)
    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=scale, causal=causal, bq=bq,
                          bk=bk, Skv=skv_true, kv_offset=kv_offset, n_g=G),
        grid=(B, KV, Skp // bk, G, Sqp // bq),
        in_specs=[
            pl.BlockSpec((1, 1, 1, bq, hd),
                         lambda b, kv, ik, g, iq: (b, kv, g, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, kv, ik, g, iq: (b, kv, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, kv, ik, g, iq: (b, kv, ik, 0)),
            pl.BlockSpec((1, 1, 1, bq, hd),
                         lambda b, kv, ik, g, iq: (b, kv, g, iq, 0)),
            pl.BlockSpec((1, 1, 1, bq),
                         lambda b, kv, ik, g, iq: (b, kv, g, iq)),
            pl.BlockSpec((1, 1, 1, bq),
                         lambda b, kv, ik, g, iq: (b, kv, g, iq)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bk, hd), lambda b, kv, ik, g, iq: (b, kv, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, kv, ik, g, iq: (b, kv, ik, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, KV, Skp, hd), k.dtype),
            jax.ShapeDtypeStruct((B, KV, Skp, hd), v.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((bk, hd), jnp.float32),
                        pltpu.VMEM((bk, hd), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary", "arbitrary")),
        interpret=interpret,
    )(q5, k, v, do5, lse5, delta5)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# custom_vjp wrapper (the trainable op)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention_trainable(q, k, v, causal=True, block_q=512, block_k=512,
                              kv_offset=0):
    out, _ = _fwd_rule(q, k, v, causal, block_q, block_k, kv_offset)
    return out


def _layout(q, k, v, block_q, block_k):
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    bq = min(block_q, _round_up(Sq, 8))
    bk = min(block_k, _round_up(Skv, 128))
    Sqp, Skp = _round_up(Sq, bq), _round_up(Skv, bk)
    qt = jnp.pad(q.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, Sqp - Sq), (0, 0)))
    kt = jnp.pad(k.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, Skp - Skv), (0, 0)))
    vt = jnp.pad(v.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, Skp - Skv), (0, 0)))
    return qt, kt, vt, bq, bk


def _fwd_rule(q, k, v, causal, block_q, block_k, kv_offset):
    B, Sq, H, hd = q.shape
    scale = hd ** -0.5
    qt, kt, vt, bq, bk = _layout(q, k, v, block_q, block_k)
    o, lse = _flash_fwd(qt, kt, vt, causal=causal, scale=scale, bq=bq, bk=bk,
                        kv_offset=kv_offset, skv_true=k.shape[1])
    out = o[:, :, :Sq].transpose(0, 2, 1, 3)
    return out, (q, k, v, o, lse)


def _bwd_rule(causal, block_q, block_k, kv_offset, res, dout):
    q, k, v, o, lse = res
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    scale = hd ** -0.5
    qt, kt, vt, bq, bk = _layout(q, k, v, block_q, block_k)
    Sqp = qt.shape[2]
    dot = jnp.pad(dout.transpose(0, 2, 1, 3),
                  ((0, 0), (0, 0), (0, Sqp - Sq), (0, 0)))
    dq, dk, dv = _flash_bwd(qt, kt, vt, o, lse, dot, causal=causal,
                            scale=scale, bq=bq, bk=bk, kv_offset=kv_offset,
                            skv_true=Skv)
    dq = dq[:, :, :Sq].transpose(0, 2, 1, 3)
    dk = dk[:, :, :Skv].transpose(0, 2, 1, 3)
    dv = dv[:, :, :Skv].transpose(0, 2, 1, 3)
    return dq, dk, dv


flash_attention_trainable.defvjp(_fwd_rule, _bwd_rule)
