"""Shared block-geometry helpers for the Pallas kernels.

Every kernel in this package tiles its operands the same way: pad to
lane/sublane-aligned shapes (``round_up``), and — for the stencil kernels —
read overlapping input blocks that carry a radius-r halo.  These helpers used
to live as underscore-private functions in ``stencil2d.py`` that the other
kernel modules reached into; they are public here so kernels depend on a
shared home instead of each other's internals.

``halo_block_spec`` also papers over a JAX API difference: newer JAX spells
overlapping (element-indexed) blocks ``pl.Element(size, padding=...)``, while
older releases (e.g. 0.4.x) spell the same thing with
``indexing_mode=pl.Unblocked(padding)``.  Both interpret the index map as
element offsets into the padding-extended array, so one index map serves
both; out-of-array halo elements are undefined and every stencil kernel masks
them before use.
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` across JAX versions (``TPUCompilerParams``
    in 0.4.x releases)."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)


def default_interpret(interpret: bool | None) -> bool:
    """Resolve a kernel's ``interpret`` argument: None means "interpret iff
    this process has no native Pallas lowering" (CPU hosts).

    Single source of truth for every Pallas kernel in this package — and for
    ``core/plan.py``, which records the resolved value on the plan so the
    dispatcher can tell an interpreted execution from a compiled one.
    """
    if interpret is not None:
        return bool(interpret)
    return jax.default_backend() == "cpu"


def round_up(v: int, m: int) -> int:
    """Smallest multiple of ``m`` that is >= ``v``."""
    return (v + m - 1) // m * m


def shift2d(xb: jnp.ndarray, dr: int, dc: int, r: int) -> jnp.ndarray:
    """Slice the halo block so result[i,j] = xb_interior[i+dr, j+dc].

    xb has r halo rows top/bottom and r halo cols left/right; the output is
    the (block_h, block_w) interior window displaced by (dr, dc).
    """
    h, w = xb.shape
    return jax.lax.slice(xb, (r + dr, r + dc), (h - r + dr, w - r + dc))


# A "resident" block must hold the whole padded grid in VMEM (~16 MB/core)
# with room for the accumulator and double buffering.
RESIDENT_VMEM_BYTES = 8 * 1024 * 1024


def resident_fits(grid_shape: tuple[int, int], itemsize: int = 4) -> bool:
    """Whether the whole (padded) grid fits one VMEM-resident block."""
    H, W = grid_shape
    return round_up(H, 8) * round_up(W, 128) * itemsize <= RESIDENT_VMEM_BYTES


def fused_block_geometry(H: int, W: int, fuse: int, r: int,
                         block_h: int = 256,
                         rim: str = "trapezoid") -> tuple[int, int, int, int]:
    """Block geometry of the temporally-fused 2D Jacobi kernel.

    Returns ``(bh, Hp, Wp, halo)``: the row-block height, the padded grid
    extents, and the per-side halo depth.  This is the single source of
    truth shared by ``jacobi_fused.py`` (which tiles with it) and the
    ``plan.py`` roofline model (which prices the rim recompute it implies).

    Rim strategies: ``"trapezoid"`` tiles rows into overlapping blocks whose
    halo deepens with the fuse depth (``fuse * r`` per side — the classic
    overlapped-tiling scheme, redundant rim recompute); ``"resident"`` keeps
    the *whole* grid in one VMEM block and re-zeroes a depth-``r`` halo
    between in-kernel iterations — no redundancy and no depth limit, legal
    only when the grid fits VMEM (:func:`resident_fits`).  The resident
    strategy is the TPU analogue of the WSE's grid-stays-in-SRAM execution
    and unlocks the fuse depths the trapezoid geometry rejects.
    """
    Wp = round_up(W, 128)
    if rim == "resident":
        Hp = round_up(H, 8)
        return Hp, Hp, Wp, r
    if rim != "trapezoid":
        raise ValueError(f"unknown rim strategy {rim!r} "
                         f"(expected 'trapezoid' or 'resident')")
    halo = fuse * r
    bh = min(block_h, round_up(H, 8))
    Hp = round_up(H, bh)
    return bh, Hp, Wp, halo


def fuse_redundancy(grid_shape: tuple[int, int], fuse: int, r: int,
                    block_h: int = 256, rim: str = "trapezoid") -> float:
    """Rim-recompute factor of the depth-``fuse`` schedule: elements each
    block touches divided by elements it owns.  1.0 means no redundant work;
    the cost model multiplies compute time by this when pricing a fuse depth.
    The resident strategy recomputes nothing (its rim is re-zeroed, not
    re-derived from a deeper halo).
    """
    if rim == "resident":
        return 1.0
    H, W = grid_shape
    bh, _, Wp, halo = fused_block_geometry(H, W, fuse, r, block_h, rim)
    return ((bh + 2 * halo) * (Wp + 2 * halo)) / (bh * Wp)


def halo_fuse_redundancy(local_shape: tuple[int, int], fuse: int,
                         r: int) -> float:
    """Rim-recompute factor of a depth-``fuse`` deep-halo schedule on one
    (h_loc, w_loc) device tile: cells updated across the fused sweep divided
    by cells owned.  Substep ``s`` of the trapezoid computes the tile
    extended by margin ``(fuse-s)*r``, so the factor grows with depth — the
    distributed analogue of :func:`fuse_redundancy`, which the halo cost
    model multiplies compute time by when pricing a fuse depth.
    """
    h, w = local_shape
    if h <= 0 or w <= 0 or fuse <= 1:
        return 1.0
    total = sum((h + 2 * (fuse - s) * r) * (w + 2 * (fuse - s) * r)
                for s in range(1, fuse + 1))
    return total / (fuse * h * w)


def halo_exchange_bytes(local_shape: tuple[int, int], fuse: int, r: int,
                        itemsize: int = 4) -> int:
    """Bytes one device moves per deep-halo exchange: two ``r*fuse``-deep
    edge strips per mesh axis, the row phase widened by the already-attached
    column halos (the corner transit).  Perimeter-proportional — the
    communication term of the halo roofline."""
    h, w = local_shape
    R = r * fuse
    return int(2 * R * (h + w + 2 * R) * itemsize)


def halo_block_spec(
    block_shape: Sequence[int],
    index_map: Callable[..., tuple],
    padding: Sequence[tuple[int, int]],
) -> pl.BlockSpec:
    """A BlockSpec whose padded dims read overlapping element-indexed blocks.

    ``block_shape`` already includes the halo extent (e.g. ``bh + 2*r``);
    ``padding[d]`` is the (lo, hi) halo depth of dim d, ``(0, 0)`` for dims
    indexed block-wise with block size 1 or the full extent — for those the
    index map value is identical under blocked and element indexing, which is
    what lets a single map serve both JAX APIs.
    """
    if hasattr(pl, "Element"):
        shape = tuple(
            pl.Element(s, padding=p) if p != (0, 0) else s
            for s, p in zip(block_shape, padding)
        )
        return pl.BlockSpec(shape, index_map)
    return pl.BlockSpec(
        tuple(block_shape), index_map,
        indexing_mode=pl.Unblocked(tuple(tuple(p) for p in padding)),
    )
