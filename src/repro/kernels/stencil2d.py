"""Direct 2D stencil Pallas kernel — the TPU-native re-think of the paper's
conv encoding (DESIGN §2).

On the WSE the grid lives in per-core SRAM and neighbour taps arrive over the
fabric.  The TPU analogue: row-tile the grid into VMEM blocks with a
radius-r halo (overlapping element-indexed reads via ``tiling.halo_block_spec``),
apply the taps as *shifted adds* on the VPU, and write back the interior.  A 5-point stencil
has no MXU-shaped reuse at C=1 — im2col conv would waste 9/5 of its MACs and
round-trip through a matmul — so the direct form is the roofline-correct
choice: arithmetic intensity ≈ 7 FLOP / 8 bytes streamed, i.e. memory-bound,
and the kernel's job is to stream HBM→VMEM exactly once per element.

Block geometry: (block_h + 2r, W) input tiles, (block_h, W) output tiles.
W rides the 128-wide lane dimension (wrapper pads W to a multiple of 128);
block_h is sublane-aligned (multiple of 8).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.stencil import StencilSpec, WeightField
from repro.kernels.tiling import (default_interpret, halo_block_spec,
                                  round_up, shift2d)


def _stencil_block(xb: jnp.ndarray, spec: StencilSpec, r: int,
                   w_ref=None) -> jnp.ndarray:
    """Shifted-add accumulation; varying taps read their per-cell weight
    block (stacked tap-major, aligned with the output tile) from ``w_ref``."""
    acc = None
    k = 0
    for off, wgt in spec.taps:
        term = shift2d(xb, off[0], off[1], r).astype(jnp.float32)
        if isinstance(wgt, WeightField):
            term = term * w_ref[k].astype(jnp.float32)
            k += 1
        else:
            term = term * np.float32(wgt)
        acc = term if acc is None else acc + term
    return acc


def _kernel(x_ref, *refs, spec: StencilSpec, r: int, block_h: int,
            H: int, W: int, bc_value: float | None):
    w_ref, o_ref = (refs[0], refs[1]) if len(refs) == 2 else (None, refs[0])
    i = pl.program_id(1)
    xb = x_ref[0]  # (block_h + 2r, Wp + 2r)
    bh2, bw2 = xb.shape
    # Global coordinates of every row/col in the halo block.
    rows = i * block_h - r + jax.lax.broadcasted_iota(jnp.int32, (bh2, bw2), 0)
    cols = -r + jax.lax.broadcasted_iota(jnp.int32, (bh2, bw2), 1)
    # Out-of-array halo reads are undefined — zero them (zero-pad semantics).
    xb = jnp.where((rows >= 0) & (rows < H) & (cols >= 0) & (cols < W), xb, 0.0)
    out = _stencil_block(xb, spec, r, w_ref)
    if bc_value is not None:
        # Fused paper mask trick: interior keeps the stencil result, the
        # boundary shell is pinned to the Dirichlet value.
        orows = rows[r:-r, r:-r] if r else rows
        ocols = cols[r:-r, r:-r] if r else cols
        interior = (orows >= 1) & (orows < H - 1) & (ocols >= 1) & (ocols < W - 1)
        out = jnp.where(interior, out, np.float32(bc_value))
    o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("spec", "block_h", "bc_value", "interpret"),
)
def stencil2d(
    x: jnp.ndarray,
    spec: StencilSpec,
    *,
    block_h: int = 256,
    bc_value: float | None = None,
    interpret: bool | None = None,
    fields: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Apply one stencil step to x: (batch, H, W).

    bc_value=None → raw stencil with zero padding (matches stencil2d_ref);
    bc_value=v    → fused Jacobi step with scalar Dirichlet BC v
                    (matches one iteration of jacobi2d_ref).
    ``fields`` optionally overrides a variable spec's baked per-cell weight
    values with a runtime (V, H, W) stack — a traced operand, so value
    changes don't recompile and gradients flow through it.
    """
    if spec.ndim != 2:
        raise ValueError("stencil2d needs a 2D spec")
    interpret = default_interpret(interpret)
    B, H, W = x.shape
    r = spec.radius
    bh = min(block_h, round_up(H, 8))
    Hp = round_up(H, bh)
    Wp = round_up(W, 128)
    xp = jnp.pad(x, ((0, 0), (0, Hp - H), (0, Wp - W)))

    kern = functools.partial(
        _kernel, spec=spec, r=r, block_h=bh, H=H, W=W, bc_value=bc_value
    )
    in_specs = [
        halo_block_spec(
            (1, bh + 2 * r, Wp + 2 * r),
            lambda b, i: (b, i * bh, 0),
            ((0, 0), (r, r), (r, r)),
        )
    ]
    operands = [xp]
    if spec.is_variable:
        # Per-cell weight fields stream as a second operand, tiled over the
        # same row blocks as the *output* (no halo — fields index the output
        # cell) and shared across the batch grid axis.
        if fields is None:
            fields = np.stack([w.array for _, w in spec.taps
                               if isinstance(w, WeightField)])
        wf = jnp.asarray(fields, jnp.float32)
        wf = jnp.pad(wf, ((0, 0), (0, Hp - H), (0, Wp - W)))
        in_specs.append(
            pl.BlockSpec((wf.shape[0], bh, Wp), lambda b, i: (0, i, 0)))
        operands.append(wf)
    out = pl.pallas_call(
        kern,
        grid=(B, Hp // bh),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bh, Wp), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hp, Wp), x.dtype),
        interpret=interpret,
    )(*operands)
    return out[:, :H, :W]
