"""Flash attention (forward) Pallas TPU kernel — online-softmax attention
whose scores live only in VMEM (arXiv:2205.14135, re-tiled for the MXU).

Purpose in this framework (§Perf C): the XLA attention path materializes
(B, H, Sq, Skv) fp32 score buffers in HBM several times per layer — the
single largest memory-term contributor in every dry-run cell.  This kernel
streams K/V blocks through VMEM with a running (m, l, acc) online softmax,
so HBM traffic collapses to q/k/v/o (≈ (2S·hd·3 + S·hd) bytes vs ≈ S²·4·k).

Grid: (batch, q_heads, Sq/bq, Skv/bk) — kv innermost ("arbitrary"), with
fp32 accumulators in VMEM scratch, causal block skipping via pl.when, and
GQA handled by the K/V index_map (head h reads kv head h//G: no broadcast
materializes).

This is the serving/forward path; training backward uses the XLA attention
(a flash backward kernel is the natural next step).  Validated against
ref.py's oracle in interpret mode (tests/test_flash_attention.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import round_up as _round_up, tpu_compiler_params

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, bq: int, bk: int, Sq: int, Skv: int,
            kv_offset: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1) - kv_offset

    run = True
    if causal:
        # skip blocks strictly above the diagonal
        run = (ik * bk - kv_offset) <= (iq * bq + bq - 1)

    @pl.when(run)
    def _block():
        q = q_ref[0, 0]                     # (bq, hd)
        k = k_ref[0, 0]                     # (bk, hd)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        valid = k_pos < Skv
        if causal:
            valid = valid & (k_pos <= q_pos)
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_ref[...]                 # (bq,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == pl.num_programs(3) - 1)
    def _flush():
        l = l_ref[...]
        o_ref[0, 0] = (acc_ref[...] / jnp.where(l == 0, 1.0, l)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "kv_offset", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    block_q: int = 512,
    block_k: int = 512,
    kv_offset: int = 0,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """q: (B, Sq, H, hd); k/v: (B, Skv, KV, hd) -> (B, Sq, H, hd).

    kv_offset: global position of kv token 0 relative to q token 0.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = hd ** -0.5

    bq = min(block_q, _round_up(Sq, 8))
    bk = min(block_k, _round_up(Skv, 128))
    Sqp = _round_up(Sq, bq)
    Skp = _round_up(Skv, bk)
    # layout: (B, heads, seq, hd) blocks
    qt = jnp.pad(q.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, Sqp - Sq), (0, 0)))
    kt = jnp.pad(k.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, Skp - Skv), (0, 0)))
    vt = jnp.pad(v.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, Skp - Skv), (0, 0)))

    kern = functools.partial(
        _kernel, scale=scale, causal=causal, bq=bq, bk=bk, Sq=Sq, Skv=Skv,
        kv_offset=kv_offset)
    out = pl.pallas_call(
        kern,
        grid=(B, H, Sqp // bq, Skp // bk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sqp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(qt, kt, vt)
    return out[:, :, :Sq].transpose(0, 2, 1, 3)


def flash_hbm_bytes(B, Sq, Skv, H, KV, hd, bytes_per_el=2) -> int:
    """Analytic HBM traffic of the kernel: q+o once, k/v per q-block pass.

    With bq=512, a (B,H) slice re-reads K/V Sq/bq times; causal halves it.
    Used by the kernel-adjusted roofline rows (§Perf C).
    """
    q_o = 2 * B * Sq * H * hd * bytes_per_el
    passes = max(1, Sq // 512)
    kv = 2 * B * Skv * KV * hd * bytes_per_el * passes * H // KV
    return q_o + kv // 2  # causal skips ~half the kv blocks
