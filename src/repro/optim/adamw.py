"""AdamW with fp32 states + fp32 master params, global-norm clipping.

Built from raw jax (no optax in this environment).  The optimizer state
shards exactly like the parameters (TP) — and like the ZeRO pattern in the
sp profile, where params/states shard over the data axis.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup -> cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    decay_steps = jnp.maximum(1.0, cfg.total_steps - cfg.warmup_steps)
    t = jnp.clip((step - cfg.warmup_steps) / decay_steps, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(params_f32: Any) -> dict:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params_f32)
    return {
        "params": params_f32,
        "m": zeros,
        "v": jax.tree.map(jnp.copy, zeros),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def apply_update(state: dict, grads: Any, cfg: AdamWConfig) -> tuple[dict, dict]:
    """One AdamW step.  grads match params' structure (any float dtype)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        step_dir = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p
        return p - lr * step_dir, m, v

    out = jax.tree.map(upd, state["params"], grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"params": new_params, "m": new_m, "v": new_v, "step": step}
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_state, metrics
