"""Roofline table from the dry-run artifacts (EXPERIMENTS §Roofline).

Per (arch × shape × mesh) cell, from artifacts/dryrun/*.json:

  compute term    = flops_per_device / 197 TFLOP/s          (bf16 peak, v5e)
  memory term     = hbm_bytes_per_device / 819 GB/s
  collective term = collective_operand_bytes_per_device / 50 GB/s/link

All three use the trip-count-aware HLO analysis (launch/hlo_cost.py) of the
SPMD-partitioned program, so they are per-device quantities; the dominant
term bounds the step time.  MODEL_FLOPS = 6·N_active·D (train) or
2·N_active·D (serve) gives the useful-compute fraction; roofline fraction =
MODEL_FLOPS_per_device/peak ÷ dominant-term — the score §Perf hillclimbs.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s
LINK_BW = 50e9           # bytes/s/link (ICI)


def load_cells(art_dir: str = "artifacts/dryrun"):
    cells = []
    for p in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(p) as f:
            rec = json.load(f)
        if "mesh" in rec and "arch" in rec:   # skip e.g. the PP proof record
            cells.append(rec)
    return cells


def terms(rec: dict) -> dict | None:
    if rec.get("status") != "OK" or "hlo_cost" not in rec:
        return None
    hc = rec["hlo_cost"]
    n_dev = rec["n_devices"]
    compute = hc["flops"] / PEAK_FLOPS
    memory = hc["hbm_bytes"] / HBM_BW
    collective = hc["collective_bytes_total"] / LINK_BW
    dominant = max(("compute", compute), ("memory", memory),
                   ("collective", collective), key=lambda kv: kv[1])
    model_flops_dev = rec["model_flops"] / n_dev
    useful_ratio = rec["model_flops"] / (hc["flops"] * n_dev) if hc["flops"] else 0.0
    bound = max(compute, memory, collective)
    roofline_frac = (model_flops_dev / PEAK_FLOPS) / bound if bound else 0.0
    return {
        "compute_s": compute, "memory_s": memory, "collective_s": collective,
        "dominant": dominant[0], "bound_s": bound,
        "useful_ratio": useful_ratio, "roofline_frac": roofline_frac,
        "temp_gb": rec["memory_analysis"]["temp_size_in_bytes"] / 1e9,
        "args_gb": rec["memory_analysis"]["argument_size_in_bytes"] / 1e9,
    }


def table(art_dir: str = "artifacts/dryrun", mesh: str = "pod16x16") -> str:
    rows = [
        "| arch | shape | compute s | memory s | collective s | dominant "
        "| useful | roofline | temp GB | args GB |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in load_cells(art_dir):
        if rec["mesh"] != mesh:
            continue
        key = f"| {rec['arch']} | {rec['shape']} "
        if rec["status"] == "SKIP":
            rows.append(key + f"| SKIP — {rec['skip_reason'][:60]} |||||||||")
            continue
        t = terms(rec)
        rows.append(
            key + f"| {t['compute_s']:.4f} | {t['memory_s']:.4f} "
            f"| {t['collective_s']:.4f} | **{t['dominant']}** "
            f"| {t['useful_ratio']:.3f} | {t['roofline_frac']:.3f} "
            f"| {t['temp_gb']:.1f} | {t['args_gb']:.2f} |")
    return "\n".join(rows)


def run():
    rows = []
    for mesh in ("pod16x16", "pod2x16x16"):
        for rec in load_cells():
            if rec["mesh"] != mesh:
                continue
            name = f"roofline/{rec['arch']}/{rec['shape']}/{mesh}"
            if rec["status"] == "SKIP":
                rows.append(f"{name},0.0,SKIP")
                continue
            t = terms(rec)
            rows.append(
                f"{name},{t['bound_s']*1e6:.1f},"
                f"dom={t['dominant']} useful={t['useful_ratio']:.3f} "
                f"roofline={t['roofline_frac']:.3f}")
    return rows


if __name__ == "__main__":
    import sys
    if len(sys.argv) > 1 and sys.argv[1] == "--markdown":
        print(table(mesh=sys.argv[2] if len(sys.argv) > 2 else "pod16x16"))
    else:
        for r in run():
            print(r)
