"""Paper Table 1: delivered performance for 2D Jacobi (X=Y=64), dense vs
convolution encodings, fp32 vs bf16 ("mixed") precision.

All encodings dispatch through the unified ``make_plan`` API
(core/plan.py), so this benchmark exercises exactly the code path users
call; each plan does its one-time work (dense-matrix build, jit) outside the
timed region.  The delivered-performance metric (Eq. 1) reports GFLOPS from
the analytic per-encoding FLOP counts (7 useful / 17 conv / 8191 dense per
element).

Also reproduces the dense path's iteration-memory analysis: one N² layer per
iteration limited the CS-1 to 7 iterations (paper §4).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import (
    BoundaryMode,
    DeliveredPerf,
    dense_layer_bytes,
    encoding_flops_per_point,
    laplace_jacobi,
    make_plan,
)

from benchmarks.common import csv_row, time_callable


def run(steps: int = 8, iters_dense: int = 7, iters_conv: int = 100,
        grid=(64, 64), kernel_steps: int = 4, kernel_iters: int = 10):
    spec = laplace_jacobi(2)
    n = grid[0] * grid[1]
    rng = np.random.default_rng(0)
    rows = []

    for dtype, label in ((jnp.float32, "fp32"), (jnp.bfloat16, "bf16")):
        x = jnp.asarray(rng.standard_normal((steps, *grid)), dtype)

        # dense encoding (Algorithm 1): 7 iterations (the CS-1 limit)
        p_dense = make_plan(spec, grid, backend="dense", bc=1.0,
                            mode=BoundaryMode.MATRIX, iters=iters_dense,
                            dtype=dtype)
        sec = time_callable(p_dense, x)
        perf = DeliveredPerf(n * steps, encoding_flops_per_point(spec, "dense", n),
                             7, iters_dense, sec)
        rows.append(csv_row(f"table1/dense/{label}", sec,
                            f"{perf.delivered_gflops:.2f} delivered GFLOPS | "
                            f"{perf.useful_gflops:.3f} useful | waste x{perf.waste_ratio:.0f}"))

        # convolution encoding (Algorithm 2), mask-trick BCs
        p_conv = make_plan(spec, grid, backend="conv", bc=1.0,
                           mode=BoundaryMode.MASK, iters=iters_conv,
                           dtype=dtype)
        sec = time_callable(p_conv, x)
        perf = DeliveredPerf(n * steps, encoding_flops_per_point(spec, "conv"),
                             7, iters_conv, sec)
        rows.append(csv_row(f"table1/conv/{label}", sec,
                            f"{perf.delivered_gflops:.2f} delivered GFLOPS | "
                            f"{perf.useful_gflops:.3f} useful | waste x{perf.waste_ratio:.1f}"))

    # what backend="auto"'s cost model picks for this cell on this host
    p_auto = make_plan(spec, grid, backend="auto", bc=1.0, iters=iters_conv)
    x = jnp.asarray(rng.standard_normal((steps, *grid)), jnp.float32)
    sec = time_callable(p_auto, x)
    perf = DeliveredPerf(n * steps,
                         encoding_flops_per_point(
                             spec, "conv" if p_auto.backend.startswith("conv")
                             else "direct"),
                         7, iters_conv, sec)
    rows.append(csv_row(f"table1/auto={p_auto.backend}/fp32", sec,
                        f"{perf.delivered_gflops:.2f} delivered GFLOPS | "
                        f"cost-model pick"))

    # direct Pallas stencil (TPU-native re-think; interpret mode on CPU)
    x = jnp.asarray(rng.standard_normal((kernel_steps, *grid)), jnp.float32)
    p_k = make_plan(spec, grid, backend="pallas", bc=1.0, iters=kernel_iters)
    sec = time_callable(p_k, x, warmup=1, iters=1)
    perf = DeliveredPerf(n * kernel_steps,
                         encoding_flops_per_point(spec, "direct"), 7,
                         kernel_iters, sec)
    rows.append(csv_row("table1/pallas-direct/fp32(interp)", sec,
                        f"{perf.delivered_gflops:.3f} delivered GFLOPS | "
                        f"waste x{perf.waste_ratio:.2f} (interpret mode)"))

    # the dense path's layer-memory wall (paper: 7 iterations max on CS-1)
    for it in (7, 8):
        mb = dense_layer_bytes(grid, it) / 1e6
        rows.append(csv_row(f"table1/dense-layer-mem/{it}iters", 0.0,
                            f"{mb:.0f} MB of N^2 layers"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
