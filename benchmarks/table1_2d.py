"""Paper Table 1: delivered performance for 2D Jacobi (X=Y=64), dense vs
convolution encodings, fp32 vs bf16 ("mixed") precision.

All encodings dispatch through the unified solver engine
(core/solver.py -> core/plan.py): each fixed-step section times the
``Solver``'s compiled chunk (its one-time work — dense-matrix build, jit —
happens outside the timed region), and the run-to-convergence section runs
the paper's actual experiment (iterate until the relative residual settles)
and reports iterations-to-convergence and seconds per iteration.  The
delivered-performance metric (Eq. 1) reports GFLOPS from the analytic
per-encoding FLOP counts (7 useful / 17 conv / 8191 dense per element).

Also reproduces the dense path's iteration-memory analysis: one N² layer per
iteration limited the CS-1 to 7 iterations (paper §4).

``run`` returns (csv rows, solver-metrics dict); benchmarks/run.py folds the
metrics into BENCH_stencil.json's stable ``solver`` section.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import (
    BoundaryMode,
    DeliveredPerf,
    Solver,
    dense_layer_bytes,
    encoding_flops_per_point,
    laplace_jacobi,
)

from benchmarks.common import csv_row, solver_metric, time_callable


def run(steps: int = 8, iters_dense: int = 7, iters_conv: int = 100,
        grid=(64, 64), kernel_steps: int = 4, kernel_iters: int = 10,
        solve_rtol: float = 1e-6, solve_max_iters: int = 20_000):
    spec = laplace_jacobi(2)
    n = grid[0] * grid[1]
    rng = np.random.default_rng(0)
    rows = []
    metrics: dict[str, dict] = {}

    def fixed(backend, iters, dtype=jnp.float32, **kw):
        return Solver(spec, grid, backend=backend, bc=1.0, rtol=None,
                      atol=None, max_iters=iters, dtype=dtype, **kw)

    for dtype, label in ((jnp.float32, "fp32"), (jnp.bfloat16, "bf16")):
        x = jnp.asarray(rng.standard_normal((steps, *grid)), dtype)

        # dense encoding (Algorithm 1): 7 iterations (the CS-1 limit)
        s_dense = fixed("dense", iters_dense, dtype,
                        mode=BoundaryMode.MATRIX)
        sec = time_callable(s_dense.plan, x)
        perf = DeliveredPerf(n * steps, encoding_flops_per_point(spec, "dense", n),
                             7, iters_dense, sec)
        name = f"table1/dense/{label}"
        rows.append(csv_row(name, sec,
                            f"{perf.delivered_gflops:.2f} delivered GFLOPS | "
                            f"{perf.useful_gflops:.3f} useful | waste x{perf.waste_ratio:.0f}"))
        metrics[name] = solver_metric(iters_dense, sec / iters_dense)

        # convolution encoding (Algorithm 2), mask-trick BCs
        s_conv = fixed("conv", iters_conv, dtype)
        sec = time_callable(s_conv.plan, x)
        perf = DeliveredPerf(n * steps, encoding_flops_per_point(spec, "conv"),
                             7, iters_conv, sec)
        name = f"table1/conv/{label}"
        rows.append(csv_row(name, sec,
                            f"{perf.delivered_gflops:.2f} delivered GFLOPS | "
                            f"{perf.useful_gflops:.3f} useful | waste x{perf.waste_ratio:.1f}"))
        metrics[name] = solver_metric(iters_conv, sec / iters_conv)

    # what backend="auto"'s cost model picks for this cell on this host
    s_auto = fixed("auto", iters_conv)
    x = jnp.asarray(rng.standard_normal((steps, *grid)), jnp.float32)
    sec = time_callable(s_auto.plan, x)
    perf = DeliveredPerf(n * steps,
                         encoding_flops_per_point(
                             spec, "conv" if s_auto.backend.startswith("conv")
                             else "direct"),
                         7, iters_conv, sec)
    name = f"table1/auto={s_auto.backend}/fp32"
    rows.append(csv_row(name, sec,
                        f"{perf.delivered_gflops:.2f} delivered GFLOPS | "
                        f"cost-model pick"))
    metrics[name] = solver_metric(iters_conv, sec / iters_conv)

    # direct Pallas stencil (TPU-native re-think; interpret mode on CPU).
    # The plan records whether Pallas actually ran interpreted — the metric
    # row carries that flag structurally (run.py folds it into the artifact's
    # interpreted_rows list) so consumers never parse the "(interp)" suffix.
    x = jnp.asarray(rng.standard_normal((kernel_steps, *grid)), jnp.float32)
    s_k = fixed("pallas", kernel_iters)
    sec = time_callable(s_k.plan, x, warmup=1, iters=1)
    perf = DeliveredPerf(n * kernel_steps,
                         encoding_flops_per_point(spec, "direct"), 7,
                         kernel_iters, sec)
    interp = bool(s_k.plan.interpreted)
    name = "table1/pallas-direct/fp32" + ("(interp)" if interp else "")
    rows.append(csv_row(name, sec,
                        f"{perf.delivered_gflops:.3f} delivered GFLOPS | "
                        f"waste x{perf.waste_ratio:.2f}"
                        + (" (interpret mode)" if interp else "")))
    metrics[name] = solver_metric(kernel_iters, sec / kernel_iters,
                                  interpreted=interp)

    # run-to-convergence: the paper's actual experiment (Jacobi iterated
    # until the relative L2 residual settles), via the solver time loop
    s = Solver(spec, grid, backend="auto", bc=1.0, rtol=solve_rtol,
               check_every=20, max_iters=solve_max_iters)
    x0 = jnp.zeros(grid, jnp.float32)
    s.solve(x0)                 # compile outside the reported wall time
    res = s.solve(x0)
    spi = res.wall_seconds / max(res.iterations, 1)
    name = f"table1/solve/auto={res.backend}"
    rows.append(csv_row(name, res.wall_seconds,
                        f"iters={res.iterations} s/iter={spi:.2e} "
                        f"residual={res.residual:.1e} converged={res.converged}"))
    metrics[name] = solver_metric(
        res.iterations, spi, mode="converged", backend=res.backend,
        residual=float(res.residual), converged=bool(res.converged))

    # the dense path's layer-memory wall (paper: 7 iterations max on CS-1)
    for it in (7, 8):
        mb = dense_layer_bytes(grid, it) / 1e6
        rows.append(csv_row(f"table1/dense-layer-mem/{it}iters", 0.0,
                            f"{mb:.0f} MB of N^2 layers"))
    return rows, metrics


if __name__ == "__main__":
    for r in run()[0]:
        print(r)
