"""Adjoint-solve benchmark: forward vs forward+backward cost (ISSUE 9).

Prices the differentiable solve (``core.adjoint.implicit_solve``): the
forward fixed point alone, then a full ``jax.value_and_grad`` through it —
one adjoint solve with the transposed operator plus the pointwise gradient
assembly.  The interesting number is the backward/forward ratio: the
implicit-function-theorem VJP costs roughly one extra solve regardless of
iteration count, where unrolled autodiff would scale with it (and reverse
through ``lax.while_loop`` is impossible outright).

``run`` returns (csv rows, metrics dict); metric keys are ``adjoint/...``
and land in BENCH_stencil.json's ``adjoint`` section (schema 6):

  {"grid": [H, W], "iters": int, "backend": str,
   "fwd_s": float, "grad_s": float, "grad_over_fwd": float}
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import heterogeneous_jacobi, implicit_solve

from benchmarks.common import csv_row, time_callable


def run(grid=(64, 64), iters: int = 200, backend: str = "conv"):
    rows = []
    metrics: dict[str, dict] = {}
    rng = np.random.default_rng(0)
    spec = heterogeneous_jacobi(1.0 + 9.0 * rng.random(grid))
    fields = jnp.asarray(spec.field_stack())
    src = jnp.asarray(rng.standard_normal(grid), jnp.float32)
    x0 = jnp.zeros(grid, jnp.float32)

    # Fixed-length solves so forward and backward run identical iteration
    # counts and the ratio is a pure adjoint-overhead measurement.
    kw = dict(backend=backend, rtol=None, atol=None, max_iters=iters)

    @jax.jit
    def fwd(f):
        return jnp.sum(implicit_solve(spec, x0, fields=f, source=src, **kw))

    grad = jax.jit(jax.value_and_grad(fwd))

    t_fwd = time_callable(fwd, fields)
    t_grad = time_callable(grad, fields)
    ratio = t_grad / max(t_fwd, 1e-12)

    name = f"adjoint/hetero-{grid[0]}x{grid[1]}/{backend}"
    rows.append(csv_row(
        f"{name}/forward", t_fwd, f"iters={iters} backend={backend}"))
    rows.append(csv_row(
        f"{name}/grad", t_grad,
        f"iters={iters} grad/fwd={ratio:.2f}x (adjoint = ~one extra solve)"))
    metrics[name] = {
        "grid": list(grid),
        "iters": int(iters),
        "backend": backend,
        "fwd_s": float(t_fwd),
        "grad_s": float(t_grad),
        "grad_over_fwd": float(ratio),
    }
    return rows, metrics
