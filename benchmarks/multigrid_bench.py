"""Multigrid benchmark: V-cycle cost and total-work reduction vs Jacobi.

Runs the paper's Table-1 solve (64x64 Laplace, Dirichlet walls, iterate to
the relative-residual target) two ways through the same dispatcher — the
single-level Jacobi time loop (``core.solver.solve``, the paper-faithful
pipeline) and the geometric-multigrid V-cycle (``core.multigrid``) — and
reports the currency the acceptance criterion is written in: *fine-grid work
units* (one unit = one stencil sweep over the finest grid, so one Jacobi
iteration costs exactly 1).  A variable-coefficient solve rides along to
price the per-cell-weight-field path.

``run`` returns (csv rows, metrics dict); metric keys are ``multigrid/...``
and land in BENCH_stencil.json's ``multigrid`` section (schema 3).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import Multigrid, heterogeneous_jacobi, laplace_jacobi, solve

from benchmarks.common import csv_row


def _mg_metric(res, jacobi_iters=None):
    m = {
        "cycles": int(res.cycles),
        "s_per_cycle": float(res.wall_seconds / max(res.cycles, 1)),
        "work_units": float(res.work_units),
        "work_per_cycle": float(res.work_per_cycle),
        "levels": len(res.level_shapes),
        "backend": res.backend,
        "residual": float(res.residual),
        "converged": bool(res.converged),
    }
    if jacobi_iters is not None:
        m["jacobi_iters"] = int(jacobi_iters)
        m["work_ratio_vs_jacobi"] = float(jacobi_iters / max(res.work_units,
                                                             1e-9))
    return m


def run(rtol: float = 1e-6, grid=(64, 64), max_iters: int = 20_000):
    rows = []
    metrics: dict[str, dict] = {}
    spec = laplace_jacobi(2)
    x0 = jnp.zeros(grid, jnp.float32)

    # Single-level Jacobi baseline: the paper's run-to-convergence solve.
    jac = solve(spec, x0, bc=1.0, rtol=rtol, check_every=20,
                max_iters=max_iters)

    # The V-cycle on the identical problem and convergence criterion.
    mg = Multigrid(spec, grid, bc=1.0, rtol=rtol)
    mg.solve(x0)                # compile outside the reported wall time
    res = mg.solve(x0)
    name = f"multigrid/table1-{grid[0]}x{grid[1]}/vcycle"
    ratio = jac.iterations / max(res.work_units, 1e-9)
    rows.append(csv_row(
        name, res.wall_seconds,
        f"cycles={res.cycles} work={res.work_units:.0f} units vs "
        f"jacobi={jac.iterations} iters ({ratio:.1f}x less work) "
        f"residual={res.residual:.1e} converged={res.converged}"))
    metrics[name] = _mg_metric(res, jacobi_iters=jac.iterations)

    # Variable-coefficient solve: per-cell weight fields through the same
    # hierarchy (odd grid — every level boundary is coarse-representable).
    rng = np.random.default_rng(0)
    n = 65
    kappa = 1.0 + 9.0 * rng.random((n, n)).astype(np.float32)
    hspec = heterogeneous_jacobi(kappa)
    hmg = Multigrid(hspec, (n, n), bc=1.0, rtol=rtol)
    hmg.solve(jnp.zeros((n, n), jnp.float32))
    hres = hmg.solve(jnp.zeros((n, n), jnp.float32))
    name = f"multigrid/hetero-{n}x{n}/vcycle"
    rows.append(csv_row(
        name, hres.wall_seconds,
        f"cycles={hres.cycles} work={hres.work_units:.0f} units "
        f"backend={hres.backend} residual={hres.residual:.1e} "
        f"converged={hres.converged}"))
    metrics[name] = _mg_metric(hres)
    return rows, metrics


if __name__ == "__main__":
    for r in run()[0]:
        print(r)
