"""Benchmark runner — one section per paper table/figure plus the roofline.
Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run [--fast]
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller step counts (CI)")
    ap.add_argument("--only", nargs="*", default=None)
    args = ap.parse_args()

    from benchmarks import (fig5_shapes, fig6_3d, roofline,
                            stencil_fuse_sweep, table1_2d)

    sections = {
        "table1": lambda: table1_2d.run(steps=4 if args.fast else 8,
                                        iters_conv=20 if args.fast else 100),
        "fig5": lambda: fig5_shapes.run(iters=20 if args.fast else 100),
        "fig6": lambda: fig6_3d.run(iters=10 if args.fast else 50),
        "stencil-fuse": stencil_fuse_sweep.run,
        "roofline": roofline.run,
    }
    failed = 0
    print("name,us_per_call,derived")
    for name, fn in sections.items():
        if args.only and name not in args.only:
            continue
        try:
            for row in fn():
                print(row, flush=True)
        except Exception:
            failed += 1
            print(f"{name},0.0,ERROR", flush=True)
            traceback.print_exc()
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
