"""Benchmark runner — one section per paper table/figure plus the roofline.
Prints ``name,us_per_call,derived`` CSV rows and (with ``--json``) writes a
machine-readable name -> us_per_call map so the perf trajectory is trackable
across commits.

  PYTHONPATH=src python -m benchmarks.run [--fast] [--only table1_2d ...]
                                          [--json BENCH_stencil.json]
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback


# --only accepts either the section key or the benchmark module name.
_ALIASES = {
    "table1_2d": "table1",
    "fig5_shapes": "fig5",
    "fig6_3d": "fig6",
    "stencil_fuse_sweep": "stencil-fuse",
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller step counts (CI)")
    ap.add_argument("--only", nargs="*", default=None)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write {row name: us_per_call} JSON")
    args = ap.parse_args()
    only = ({_ALIASES.get(o, o) for o in args.only} if args.only else None)

    from benchmarks import (fig5_shapes, fig6_3d, roofline,
                            stencil_fuse_sweep, table1_2d)

    sections = {
        "table1": lambda: table1_2d.run(steps=4 if args.fast else 8,
                                        iters_conv=20 if args.fast else 100),
        "fig5": lambda: fig5_shapes.run(iters=20 if args.fast else 100),
        "fig6": lambda: fig6_3d.run(iters=10 if args.fast else 50),
        "stencil-fuse": stencil_fuse_sweep.run,
        "roofline": roofline.run,
    }
    failed = 0
    if only:
        unknown = only - sections.keys()
        if unknown:
            print(f"# unknown --only section(s) {sorted(unknown)}; known: "
                  f"{sorted(sections) + sorted(_ALIASES)}", file=sys.stderr)
            failed += len(unknown)
    results: dict[str, float] = {}
    print("name,us_per_call,derived")
    for name, fn in sections.items():
        if only and name not in only:
            continue
        try:
            for row in fn():
                print(row, flush=True)
                parts = row.split(",")
                if len(parts) >= 2:
                    try:
                        us = float(parts[1])
                    except ValueError:
                        continue
                    if us > 0.0:
                        # Analytic rows (memory models, roofline bounds)
                        # print a literal 0.0 — not timings, keep them out
                        # of the perf-trajectory artifact.
                        results[parts[0]] = us
        except Exception:
            failed += 1
            print(f"{name},0.0,ERROR", flush=True)
            traceback.print_exc()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
        print(f"# wrote {len(results)} rows to {args.json}", file=sys.stderr)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
