"""Benchmark runner — one section per paper table/figure plus the roofline.
Prints ``name,us_per_call,derived`` CSV rows and (with ``--json``) writes a
machine-readable artifact so the perf trajectory is trackable across commits.

JSON schema (stable, version 7):

  {"schema": 7,
   "us_per_call": {row name: microseconds per timed call},
   "interpreted_rows": [row names whose timing came from interpret-mode
                        Pallas — structurally tagged so consumers exclude
                        them from fastest-backend comparisons instead of
                        pattern-matching "(interp)" name suffixes],
   "solver":      {row name: {"mode": "fixed"|"converged",
                              "iters": int, "s_per_iter": float,
                              # interpret-mode rows carry "interpreted": true
                              # converged rows additionally carry:
                              "backend": str, "residual": float,
                              "converged": bool}},
   "multigrid":   {row name: {"cycles": int, "s_per_cycle": float,
                              "work_units": float, "work_per_cycle": float,
                              "levels": int, "backend": str,
                              "residual": float, "converged": bool,
                              # rows with a Jacobi baseline additionally:
                              "jacobi_iters": int,
                              "work_ratio_vs_jacobi": float}},
   "autotune":    {row name: {"backend": str,
                              "source": "roofline"|"tuned"|"explicit",
                              "fuse": int, "rim": str|null,
                              "s_per_iter": float, "interpreted": bool,
                              "candidates_measured": int}},
   "scaling":     {row name: {"mesh": [n_row, n_col], "grid": [H, W],
                              "fuse": int, "iters": int,
                              # timed rows (weak/strong/fuse-sweep):
                              "s_per_iter": float, "comm_rounds": int,
                              # the scaling/equivalence row instead:
                              "max_err": float, "converged": bool}},
   "adjoint":     {row name: {"grid": [H, W], "iters": int, "backend": str,
                              "fwd_s": float, "grad_s": float,
                              "grad_over_fwd": float}},
   "serving":     {row name: {"requests": int, "solves_per_sec": float,
                              "p50_ms": float, "p99_ms": float, ...} and
                   the serving/*/speedup + serving/*/cache summary rows —
                   see benchmarks/serving_bench.py}}

Sections may return either a list of CSV rows or (rows, metrics dict);
metric keys starting with ``multigrid/`` land in the ``multigrid`` section,
``autotune/`` in ``autotune``, ``scaling/`` in ``scaling`` (the
forced-8-device distributed rows from benchmarks/scaling_bench.py),
``adjoint/`` in ``adjoint`` (differentiable-solve forward-vs-grad cost),
``serving/`` in ``serving`` (plan-cache + coalescing engine throughput),
everything else in ``solver``.  Any metric row carrying
``"interpreted": true`` also lands its name in the top-level
``interpreted_rows`` list.  A section whose run produced no metric rows is
omitted from the payload entirely — an empty ``{}`` section is invalid
(``serving_bench.validate_serving`` rejects it).

  PYTHONPATH=src python -m benchmarks.run [--fast] [--only table1_2d ...]
                                          [--json BENCH_stencil.json]
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback


# --only accepts either the section key or the benchmark module name.
_ALIASES = {
    "table1_2d": "table1",
    "fig5_shapes": "fig5",
    "fig6_3d": "fig6",
    "stencil_fuse_sweep": "stencil-fuse",
    "multigrid_bench": "multigrid",
    "autotune_bench": "autotune",
    "scaling_bench": "scaling",
    "adjoint_bench": "adjoint",
    "serving_bench": "serving",
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller step counts (CI)")
    ap.add_argument("--only", nargs="*", default=None)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the schema-7 JSON artifact "
                         "({schema, us_per_call, interpreted_rows, solver, "
                         "multigrid, autotune, scaling, adjoint, serving})")
    args = ap.parse_args()
    only = ({_ALIASES.get(o, o) for o in args.only} if args.only else None)

    from benchmarks import (adjoint_bench, autotune_bench, fig5_shapes,
                            fig6_3d, multigrid_bench, roofline, scaling_bench,
                            serving_bench, stencil_fuse_sweep, table1_2d)

    sections = {
        "table1": lambda: table1_2d.run(steps=4 if args.fast else 8,
                                        iters_conv=20 if args.fast else 100),
        "fig5": lambda: fig5_shapes.run(iters=20 if args.fast else 100),
        "fig6": lambda: fig6_3d.run(iters=10 if args.fast else 50),
        "stencil-fuse": stencil_fuse_sweep.run,
        "roofline": roofline.run,
        "multigrid": lambda: multigrid_bench.run(
            rtol=1e-5 if args.fast else 1e-6),
        "autotune": lambda: autotune_bench.run(
            iters=20 if args.fast else 100,
            tune_iters=20, repeats=1 if args.fast else 3),
        "scaling": lambda: scaling_bench.run(smoke=args.fast),
        "adjoint": lambda: adjoint_bench.run(
            iters=50 if args.fast else 200),
        "serving": lambda: serving_bench.run(smoke=args.fast),
    }
    failed = 0
    if only:
        unknown = only - sections.keys()
        if unknown:
            print(f"# unknown --only section(s) {sorted(unknown)}; known: "
                  f"{sorted(sections) + sorted(_ALIASES)}", file=sys.stderr)
            failed += len(unknown)
    results: dict[str, float] = {}
    solver_metrics: dict[str, dict] = {}
    mg_metrics: dict[str, dict] = {}
    tune_metrics: dict[str, dict] = {}
    scaling_metrics: dict[str, dict] = {}
    adjoint_metrics: dict[str, dict] = {}
    serving_metrics: dict[str, dict] = {}
    interpreted_rows: list[str] = []
    print("name,us_per_call,derived")
    for name, fn in sections.items():
        if only and name not in only:
            continue
        try:
            out = fn()
            if isinstance(out, tuple):
                rows, metrics = out
                for k, v in metrics.items():
                    if k.startswith("multigrid/"):
                        mg_metrics[k] = v
                    elif k.startswith("autotune/"):
                        tune_metrics[k] = v
                    elif k.startswith("scaling/"):
                        scaling_metrics[k] = v
                    elif k.startswith("adjoint/"):
                        adjoint_metrics[k] = v
                    elif k.startswith("serving/"):
                        serving_metrics[k] = v
                    else:
                        solver_metrics[k] = v
                    if isinstance(v, dict) and v.get("interpreted"):
                        interpreted_rows.append(k)
            else:
                rows = out
            for row in rows:
                print(row, flush=True)
                parts = row.split(",")
                if len(parts) >= 2:
                    try:
                        us = float(parts[1])
                    except ValueError:
                        continue
                    if us > 0.0:
                        # Analytic rows (memory models, roofline bounds)
                        # print a literal 0.0 — not timings, keep them out
                        # of the perf-trajectory artifact.
                        results[parts[0]] = us
        except Exception:
            failed += 1
            print(f"{name},0.0,ERROR", flush=True)
            traceback.print_exc()
    if args.json:
        payload = {"schema": 7, "us_per_call": results,
                   "interpreted_rows": sorted(interpreted_rows)}
        # A section that ran produces rows; one that was skipped (--only) or
        # errored would otherwise land as {} — omit it, empty-dict sections
        # fail validation (serving_bench.validate_serving).
        for key, metrics in (("solver", solver_metrics),
                             ("multigrid", mg_metrics),
                             ("autotune", tune_metrics),
                             ("scaling", scaling_metrics),
                             ("adjoint", adjoint_metrics),
                             ("serving", serving_metrics)):
            if metrics:
                payload[key] = metrics
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"# wrote {len(results)} timing rows + {len(solver_metrics)} "
              f"solver rows + {len(mg_metrics)} multigrid rows + "
              f"{len(tune_metrics)} autotune rows + {len(scaling_metrics)} "
              f"scaling rows + {len(adjoint_metrics)} adjoint rows + "
              f"{len(serving_metrics)} serving rows to "
              f"{args.json}", file=sys.stderr)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
