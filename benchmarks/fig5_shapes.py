"""Paper Fig 5: delivered performance of the conv encoding as the per-step
input tensor shape varies {32x64, 64x64, 128x64, 128x128} at fixed total
problem size — the paper's fabric-utilisation sweep (27%/27%/45%/67% of the
CS-1).  On TPU the analogue is VMEM-tile occupancy; on this CPU we measure
the relative throughput and report the paper's metric.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    DeliveredPerf,
    DirichletBC,
    conv_jacobi_2d,
    encoding_flops_per_point,
    laplace_jacobi,
)
from benchmarks.common import csv_row, time_callable

SHAPES = [(32, 64), (64, 64), (128, 64), (128, 128)]


def run(total_elements: int = 2 * 64 * 64 * 8, iters: int = 100):
    spec = laplace_jacobi(2)
    bc = DirichletBC(1.0)
    rng = np.random.default_rng(0)
    rows = []
    for grid in SHAPES:
        n = grid[0] * grid[1]
        steps = max(1, total_elements // n)
        x = jnp.asarray(rng.standard_normal((steps, *grid)), jnp.float32)
        f = jax.jit(lambda xx: conv_jacobi_2d(xx, spec, bc, iters))
        sec = time_callable(f, x)
        perf = DeliveredPerf(n * steps, encoding_flops_per_point(spec, "conv"),
                             7, iters, sec)
        rows.append(csv_row(f"fig5/{grid[0]}x{grid[1]}", sec,
                            f"{perf.delivered_gflops:.2f} delivered GFLOPS | "
                            f"{steps} steps x {n} elems"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
