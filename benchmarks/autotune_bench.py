"""Autotune before/after: roofline pick vs measured-table pick on the
Table-1 cell (and a second, larger cell to exercise bucket matching).

``before`` lowers ``backend="auto"`` with the tuned table disabled
(``tuned=None``) — the pure analytic roofline pick.  The tuner then measures
every legal schedule for the cell (``core/autotune.py``), and ``after``
lowers the same cell against the freshly measured table.  Both plans are
wall-clock timed through the same harness, so the artifact row pair answers
"did the measured table actually beat the model on this host?".

``run`` returns (csv rows, metrics); benchmarks/run.py folds metric keys
prefixed ``autotune/`` into BENCH_stencil.json's ``autotune`` section.

Regenerate the committed table with:

  PYTHONPATH=src python -m benchmarks.autotune_bench --write

and validate it with ``scripts/ci.sh --tune-check``.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import laplace_jacobi, make_plan
from repro.core.autotune import (
    TunedTable,
    autotune_cell,
    default_table_path,
    dtype_key,
    spec_family,
)

from benchmarks.common import csv_row, time_callable


def _plan_metric(plan, s_per_iter: float, n_candidates: int = 0) -> dict:
    """One row of BENCH_stencil.json's ``autotune`` section."""
    return {
        "backend": plan.backend,
        "source": plan.source,
        "fuse": int(plan.fuse),
        "rim": plan.rim,
        "s_per_iter": float(s_per_iter),
        "interpreted": bool(plan.interpreted),
        "candidates_measured": int(n_candidates),
    }


def run(grid=(64, 64), iters: int = 100, tune_iters: int = 20,
        steps: int = 4, repeats: int = 3, table: TunedTable | None = None):
    spec = laplace_jacobi(2)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((steps, *grid)), jnp.float32)
    rows: list[str] = []
    metrics: dict[str, dict] = {}

    # -- before: pure roofline dispatch (tuned table disabled) --------------
    before = make_plan(spec, grid, backend="auto", bc=1.0, iters=iters,
                       tuned=None)
    sec_b = time_callable(before, x, iters=repeats)
    name = f"autotune/before={before.backend}/fp32"
    rows.append(csv_row(name, sec_b,
                        f"roofline pick fuse={before.fuse} "
                        f"s/iter={sec_b / iters:.2e}"))
    metrics[name] = _plan_metric(before, sec_b / iters)

    # -- tune: measure every legal schedule for the cell --------------------
    table = autotune_cell(spec, grid, iters=tune_iters, bc=1.0,
                          table=table, repeats=repeats)
    n_cand = len(table)

    # -- after: dispatch against the freshly measured table -----------------
    after = make_plan(spec, grid, backend="auto", bc=1.0, iters=iters,
                      tuned=table)
    sec_a = time_callable(after, x, iters=repeats)
    name = f"autotune/after={after.backend}/fp32"
    rows.append(csv_row(name, sec_a,
                        f"{after.source} pick fuse={after.fuse} "
                        f"rim={after.rim} s/iter={sec_a / iters:.2e} "
                        f"({n_cand} schedules measured)"))
    metrics[name] = _plan_metric(after, sec_a / iters, n_cand)

    # The winning measured schedule itself, for the trajectory record.
    entry = table.lookup(_device_kind(), spec_family(spec), grid,
                         dtype_key(jnp.float32))
    if entry is not None:
        key = "autotune/best-entry"
        metrics[key] = {
            "backend": entry.backend, "source": "tuned",
            "fuse": int(entry.fuse), "rim": entry.rim,
            "s_per_iter": entry.us_per_iter * 1e-6,
            "interpreted": bool(entry.interpreted),
            "candidates_measured": n_cand,
        }
        rows.append(csv_row(key, entry.us_per_iter * 1e-6 * iters,
                            f"{entry.backend} fuse={entry.fuse} "
                            f"block_h={entry.block_h} rim={entry.rim}"))
    return rows, metrics


def _device_kind() -> str:
    import jax
    return jax.default_backend()


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--write", nargs="?", const=default_table_path(),
                    default=None, metavar="PATH",
                    help="persist the measured table (default path: the "
                         "committed TUNED_stencil.json)")
    ap.add_argument("--grid", type=int, nargs=2, default=(64, 64))
    ap.add_argument("--iters", type=int, default=100)
    ap.add_argument("--tune-iters", type=int, default=20)
    args = ap.parse_args(argv)
    table = TunedTable()
    rows, _ = run(grid=tuple(args.grid), iters=args.iters,
                  tune_iters=args.tune_iters, table=table)
    for r in rows:
        print(r)
    if args.write:
        table.save(args.write)
        print(f"# wrote {len(table)} tuned entries to {args.write}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
