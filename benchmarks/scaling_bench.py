"""Distributed scaling benchmark — the paper's wafer-scaling story on the
forced-8-host-device mesh.

The WSE papers report weak/strong scaling of the halo-decomposed stencil;
this benchmark records the TPU-mesh analogue for the ``halo`` backend plus
the communication-avoiding fuse sweep this repo adds:

  * **weak scaling** — fixed 64x64 local tile over growing meshes (1x1 →
    2x4): s/iter should stay roughly flat as devices are added;
  * **strong scaling** — fixed global grid over the same meshes: s/iter
    should drop as the tile shrinks;
  * **fuse sweep** — fixed 2x4 mesh, fuse depth 1/2/4: ``ppermute`` rounds
    drop by the fuse depth (``halo_comm_rounds`` — analytic: ``lax.scan``
    keeps the HLO rolled, so the trip count is the round count) while
    measured s/iter must not regress;
  * **equivalence** — a converged fused distributed solve against the
    single-device reference solve (max abs error).

The measurements need more than one device, and ``XLA_FLAGS=
--xla_force_host_platform_device_count=8`` must be set before jax imports —
so ``run()`` (the benchmarks/run.py section) spawns a child process
(``--child``) and parses its JSON back.  Metric keys are prefixed
``scaling/`` and land in BENCH_stencil.json's schema-5 ``scaling`` section.

CLI:

  PYTHONPATH=src python -m benchmarks.scaling_bench [--smoke] [--json PATH]
  PYTHONPATH=src python -m benchmarks.scaling_bench --validate PATH
  PYTHONPATH=src python -m benchmarks.scaling_bench --write-tuned [PATH]

``--smoke`` is the CI tier (``scripts/ci.sh --scaling-smoke``): one weak-
scaling row plus the fuse sweep and equivalence check.  ``--validate``
checks a written artifact's ``scaling`` section (structure + the >=2x
comm-round reduction at fuse>=2).  ``--write-tuned`` measures the halo
fuse-depth sweep on the 2x4 mesh (``core/autotune.py::autotune_halo_cell``)
and merges the mesh-keyed entries into the committed TUNED_stencil.json.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_CHILD_MARK = "SCALING_JSON:"
_DEVICES = 8
WEAK_MESHES = ((1, 1), (1, 2), (2, 2), (2, 4))
FUSE_SWEEP = (1, 2, 4)


# ---------------------------------------------------------------------------
# Child: runs under the forced-device flag, prints metrics as JSON
# ---------------------------------------------------------------------------

def _child(cfg: dict) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import laplace_jacobi, solve
    from repro.core.distributed import halo_comm_rounds
    from repro.core.solver import Solver

    from benchmarks.common import time_callable

    smoke = cfg["smoke"]
    spec = laplace_jacobi(2)
    rng = np.random.default_rng(0)
    metrics: dict[str, dict] = {}
    repeats = 1 if smoke else 3

    def timed_plan(grid, mesh_shape, fuse, iters):
        mesh = jax.make_mesh(mesh_shape, ("data", "model"))
        sv = Solver(spec, grid, backend="halo", mesh=mesh, bc=1.0,
                    rtol=None, atol=None, max_iters=iters, fuse=fuse,
                    tuned=None)
        x = jnp.asarray(rng.standard_normal((1, *grid)), jnp.float32)
        sec = time_callable(sv.plan, x, iters=repeats)
        return {
            "mesh": list(mesh_shape), "grid": list(grid),
            "local": [grid[0] // mesh_shape[0], grid[1] // mesh_shape[1]],
            "fuse": int(sv.fuse), "iters": int(iters),
            "s_per_iter": sec / iters,
            "comm_rounds": halo_comm_rounds(iters, sv.fuse),
        }

    # -- weak scaling: fixed local tile, growing mesh -----------------------
    local = (64, 64)
    iters = 8 if smoke else 32
    meshes = WEAK_MESHES[-1:] if smoke else WEAK_MESHES
    for ms in meshes:
        grid = (local[0] * ms[0], local[1] * ms[1])
        metrics[f"scaling/weak/{ms[0]}x{ms[1]}"] = timed_plan(
            grid, ms, 1, iters)

    # -- strong scaling: fixed global grid, growing mesh --------------------
    if not smoke:
        grid = (128, 128)
        for ms in WEAK_MESHES:
            metrics[f"scaling/strong/{ms[0]}x{ms[1]}"] = timed_plan(
                grid, ms, 1, iters)

    # -- fuse sweep on the full 2x4 mesh ------------------------------------
    ms = WEAK_MESHES[-1]
    grid = (64, 128) if smoke else (128, 256)
    sweep_iters = 8 if smoke else 16
    base = None
    for f in FUSE_SWEEP:
        row = timed_plan(grid, ms, f, sweep_iters)
        if base is None:
            base = row
        row["rounds_ratio_vs_f1"] = row["comm_rounds"] / base["comm_rounds"]
        row["s_per_iter_ratio_vs_f1"] = row["s_per_iter"] / base["s_per_iter"]
        metrics[f"scaling/fuse/f{f}"] = row

    # -- converged fused solve vs the single-device reference ---------------
    g = (16, 24)
    mesh = jax.make_mesh(ms, ("data", "model"))
    x0 = jnp.asarray(rng.standard_normal(g), jnp.float32)
    dist = solve(spec, x0, backend="halo", mesh=mesh, bc=1.0, fuse=4,
                 check_every=16, max_iters=2000, tuned=None)
    ref = solve(spec, x0, backend="reference", bc=1.0, check_every=16,
                max_iters=2000)
    err = float(jnp.max(jnp.abs(dist.x - ref.x)))
    metrics["scaling/equivalence"] = {
        "mesh": list(ms), "grid": list(g), "fuse": int(dist.fuse),
        "iters": int(dist.iterations), "max_err": err,
        "converged": bool(dist.converged) and bool(ref.converged),
    }
    return metrics


# ---------------------------------------------------------------------------
# Parent: spawn the child, parse, format
# ---------------------------------------------------------------------------

def _repo_root() -> str:
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _spawn_child(mode: str, cfg: dict, timeout: int = 1800) -> dict:
    root = _repo_root()
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={_DEVICES}"
                        ).strip()
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root] +
        ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.scaling_bench",
         f"--{mode}", json.dumps(cfg)],
        capture_output=True, text=True, timeout=timeout, env=env, cwd=root)
    if r.returncode != 0:
        raise RuntimeError(
            f"scaling child failed:\n{r.stdout}\n{r.stderr[-4000:]}")
    for line in r.stdout.splitlines():
        if line.startswith(_CHILD_MARK):
            return json.loads(line[len(_CHILD_MARK):])
    raise RuntimeError(f"scaling child printed no result:\n{r.stdout}")


def run(smoke: bool = False):
    """The benchmarks/run.py section: (csv rows, ``scaling/``-keyed metrics).

    Spawns the forced-8-device child; every metric row lands in the JSON
    artifact's ``scaling`` section (schema 5).
    """
    from benchmarks.common import csv_row
    metrics = _spawn_child("child", {"smoke": smoke})
    rows = []
    for name in sorted(metrics):
        m = metrics[name]
        if "s_per_iter" in m:
            rows.append(csv_row(
                name, m["s_per_iter"] * m["iters"],
                f"mesh={m['mesh'][0]}x{m['mesh'][1]} fuse={m['fuse']} "
                f"s/iter={m['s_per_iter']:.2e} rounds={m['comm_rounds']}"))
        else:
            rows.append(csv_row(
                name, 0.0, f"max_err={m['max_err']:.2e} "
                f"converged={m['converged']}"))
    return rows, metrics


# ---------------------------------------------------------------------------
# Validation (scripts/ci.sh --scaling-smoke)
# ---------------------------------------------------------------------------

def validate_scaling(data: dict) -> list[str]:
    """Errors in an artifact's ``scaling`` section; [] means valid.

    Accepts either a full BENCH_stencil.json (schema 5/6) or the mini
    artifact ``--json`` writes.  Beyond structure, this enforces the
    acceptance bar: fuse>=2 must record at most half the ppermute rounds of
    fuse=1, and the converged distributed solve must match the reference to
    1e-5.
    """
    errors: list[str] = []
    if "schema" in data and data["schema"] not in (5, 6, 7):
        errors.append(f"schema {data['schema']!r} not in (5, 6, 7)")
    sc = data.get("scaling")
    if not isinstance(sc, dict) or not sc:
        return errors + ["missing or empty 'scaling' section"]
    weak = [k for k in sc if k.startswith("scaling/weak/")]
    if not weak:
        errors.append("no scaling/weak/* rows")
    for k, m in sc.items():
        if not isinstance(m, dict):
            errors.append(f"{k}: not an object")
            continue
        if "s_per_iter" in m and not m["s_per_iter"] > 0:
            errors.append(f"{k}: non-positive s_per_iter")
        if "comm_rounds" in m and (not isinstance(m["comm_rounds"], int)
                                   or m["comm_rounds"] < 1):
            errors.append(f"{k}: malformed comm_rounds")
    f1 = sc.get("scaling/fuse/f1")
    deep = [m for k, m in sc.items()
            if k.startswith("scaling/fuse/f") and isinstance(m, dict)
            and m.get("fuse", 1) >= 2]
    if f1 is None or not deep:
        errors.append("fuse sweep must record f1 and at least one f>=2 row")
    elif not any(m["comm_rounds"] * 2 <= f1["comm_rounds"] for m in deep):
        errors.append(
            f"no fuse>=2 row halves the ppermute rounds of fuse=1 "
            f"({f1['comm_rounds']} rounds at f1)")
    eq = sc.get("scaling/equivalence")
    if eq is None:
        errors.append("missing scaling/equivalence row")
    else:
        if not eq.get("converged"):
            errors.append("equivalence solve did not converge")
        if not eq.get("max_err", 1.0) <= 1e-5:
            errors.append(f"equivalence max_err {eq.get('max_err')} > 1e-5")
    return errors


# ---------------------------------------------------------------------------
# Tuned-table persistence (--write-tuned)
# ---------------------------------------------------------------------------

def _child_tune(cfg: dict) -> dict:
    import jax

    from repro.core import laplace_jacobi
    from repro.core.autotune import TunedTable, autotune_halo_cell

    mesh = jax.make_mesh(tuple(cfg["mesh"]), ("data", "model"))
    table = autotune_halo_cell(laplace_jacobi(2), tuple(cfg["grid"]), mesh,
                               iters=cfg["iters"], bc=1.0, verbose=True)
    return table.to_json()


def write_tuned(path: str, grid=(128, 256), mesh=(2, 4),
                iters: int = 16) -> int:
    """Measure halo schedules on the forced mesh and merge into ``path``."""
    from repro.core.autotune import TunedTable
    data = _spawn_child("child-tune", {"grid": list(grid),
                                       "mesh": list(mesh), "iters": iters})
    measured = TunedTable.parse(data)
    table = TunedTable.load(path)
    for e in measured.entries:
        table.add(e)
    table.save(path)
    return len(measured)


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="one weak-scaling row + fuse sweep (CI tier)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write {'schema': 5, 'scaling': ...} to PATH")
    ap.add_argument("--validate", default=None, metavar="PATH",
                    help="validate an artifact's scaling section and exit")
    ap.add_argument("--write-tuned", nargs="?", const="default", default=None,
                    metavar="PATH", help="measure halo schedules on the 2x4 "
                    "mesh into the tuned table (default: the committed one)")
    # internal: child modes run under the forced-device flag
    ap.add_argument("--child", default=None, help=argparse.SUPPRESS)
    ap.add_argument("--child-tune", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    if args.child is not None:
        print(_CHILD_MARK + json.dumps(_child(json.loads(args.child))))
        return 0
    if args.child_tune is not None:
        print(_CHILD_MARK + json.dumps(_child_tune(json.loads(
            args.child_tune))))
        return 0
    if args.validate is not None:
        with open(args.validate) as f:
            errors = validate_scaling(json.load(f))
        if errors:
            for e in errors:
                print(f"SCALING-CHECK FAIL: {e}")
            return 1
        print(f"scaling-check OK: {args.validate}")
        return 0
    if args.write_tuned is not None:
        from repro.core.autotune import default_table_path
        path = default_table_path() if args.write_tuned == "default" \
            else args.write_tuned
        n = write_tuned(path)
        print(f"# merged {n} mesh-keyed halo entries into {path}")
        return 0

    rows, metrics = run(smoke=args.smoke)
    print("name,us_per_call,derived")
    for r in rows:
        print(r)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"schema": 5, "scaling": metrics}, f, indent=2,
                      sort_keys=True)
            f.write("\n")
        print(f"# wrote {len(metrics)} scaling rows to {args.json}",
              file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
