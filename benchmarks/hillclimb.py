"""Hillclimb harness: lower one cell with config overrides, print the three
roofline terms (EXPERIMENTS §Perf methodology).

  PYTHONPATH=src python -m benchmarks.hillclimb --arch glm4-9b \\
      --shape train_4k --set remat_group=8 q_chunk=512

Runs in-process; invoke once per iteration (fresh XLA state per run).
"""
import argparse
import dataclasses
import json
import os
import time


def _force_host_devices(n: int = 512) -> None:
    """Expose ``n`` fake host devices for the mesh dry-run by *appending* to
    XLA_FLAGS.  Only ``main()`` calls this — importing the module must never
    mutate process env (clobbering a caller's own XLA_FLAGS was a bug), and
    an already-present device-count flag is left alone.
    """
    if "--xla_force_host_platform_device_count" in os.environ.get(
            "XLA_FLAGS", ""):
        return
    flag = f"--xla_force_host_platform_device_count={n}"
    os.environ["XLA_FLAGS"] = f"{os.environ.get('XLA_FLAGS', '')} {flag}".strip()


def parse_override(s: str):
    k, v = s.split("=", 1)
    for cast in (int, float):
        try:
            return k, cast(v)
        except ValueError:
            continue
    return k, v


def run(arch: str, shape: str, overrides: dict, multi_pod=False,
        device_kind: str = "tpu") -> dict:
    import jax
    from repro.core.plan import DEVICE_PROFILES
    from repro.launch import hlo_cost
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import (
        input_shardings, input_specs, make_cell, make_sharder, make_step_fn,
    )

    # Price against the same per-device table the stencil cost model uses
    # (core/plan.py DEVICE_PROFILES) — the three roofline denominators used
    # to be free-floating constants here that could drift from the model.
    prof = DEVICE_PROFILES[device_kind]

    cell = make_cell(arch, shape)
    if overrides:
        cell = dataclasses.replace(cell, cfg=dataclasses.replace(
            cell.cfg, **overrides))
        from repro.models.model_zoo import build
        cell = dataclasses.replace(cell, api=build(cell.cfg))
    mesh = make_production_mesh(multi_pod=multi_pod)
    sharder = make_sharder(cell, mesh)
    structs, dims = input_specs(cell)
    in_sh = input_shardings(cell, sharder, structs, dims)
    step = make_step_fn(cell, sharder)
    t0 = time.time()
    with mesh:
        compiled = jax.jit(step, in_shardings=in_sh).lower(*structs).compile()
    r = hlo_cost.analyze(compiled.as_text())
    mem = compiled.memory_analysis()
    out = {
        "arch": arch, "shape": shape, "overrides": overrides,
        "device_kind": device_kind,
        "compute_s": r["flops"] / prof.matmul_flops,
        "memory_s": r["hbm_bytes"] / prof.mem_bw,
        "collective_s": r["collective_bytes_total"] / prof.collective_bw,
        "flops_per_dev": r["flops"],
        "hbm_gb_per_dev": r["hbm_bytes"] / 1e9,
        "coll_gb_per_dev": r["collective_bytes_total"] / 1e9,
        "temp_gb": mem.temp_size_in_bytes / 1e9,
        "args_gb": mem.argument_size_in_bytes / 1e9,
        "compile_s": round(time.time() - t0, 1),
    }
    n_active = cell.cfg.active_param_count()
    tokens = cell.batch * (cell.seq if cell.kind in ("train", "prefill") else 1)
    mult = 6 if cell.kind == "train" else 2
    model_flops_dev = mult * n_active * tokens / mesh.size
    bound = max(out["compute_s"], out["memory_s"], out["collective_s"])
    out["useful_ratio"] = model_flops_dev / r["flops"] if r["flops"] else 0
    out["roofline_frac"] = (model_flops_dev / prof.matmul_flops) / bound \
        if bound else 0
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", nargs="*", default=[])
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--device-kind", default="tpu",
                    help="DEVICE_PROFILES row to price the roofline against")
    args = ap.parse_args()
    _force_host_devices()
    overrides = dict(parse_override(s) for s in args.set)
    out = run(args.arch, args.shape, overrides, args.multipod,
              device_kind=args.device_kind)
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
