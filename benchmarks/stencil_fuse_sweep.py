"""Temporal-blocking sweep (§Perf A3): analytic TPU roofline of the fused
Jacobi kernel vs fuse depth T, plus interpret-mode correctness at each T.

  delivered(T) = min(peak_compute / redundancy(T), AI(T) * HBM_bw)
  AI(T)        = useful_flops_per_point * T / bytes_per_point
  redundancy(T) = rim-recompute factor of the depth-T trapezoid
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import laplace_jacobi
from repro.kernels import jacobi2d
from repro.kernels.ref import jacobi2d_ref

PEAK = 197e12
HBM = 819e9
FLOPS_PER_PT = 9            # 7 stencil + 2 BC
BYTES_PER_PT = 4            # fp32 in+out amortized over streaming (2+2)


def run(block_h: int = 512, width: int = 2048):
    spec = laplace_jacobi(2)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1, 32, 64)), jnp.float32)
    rows = []
    for T in (1, 2, 4, 8, 16, 32, 64, 128):
        ai = FLOPS_PER_PT * T / BYTES_PER_PT
        redundancy = ((block_h + 2 * T) * (width + 2 * T)) / (block_h * width)
        bound = min(PEAK / redundancy, ai * HBM) / redundancy
        vmem_mb = (block_h + 2 * T) * (width + 2 * T) * 4 / 1e6
        # correctness at small scale (interpret mode) for fusable depths
        err = ""
        if T <= 8:
            out = jacobi2d(x, spec, bc_value=1.0, iterations=8 if T <= 8 else T,
                           fuse=min(T, 8), block_h=8)
            ref = jacobi2d_ref(x, spec, 1.0, 8)
            err = f" max_err={float(jnp.abs(out - ref).max()):.1e}"
        rows.append(
            f"stencil-fuse/T={T},0.0,AI={ai:.0f} flop/B | useful bound "
            f"{bound/1e12:.1f} TFLOP/s ({bound/PEAK:.1%} of peak) | "
            f"VMEM {vmem_mb:.1f} MB{err}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
