"""Shared benchmark helpers: wall-clock measurement of jitted callables and
the paper's delivered-performance reporting (Eq. 1).

CPU measurement note: this container measures *relative* encoding costs on
one CPU core — exactly the paper's framing ("a metric ... useful to compare
the relative performance of hardware technologies, rather than ... absolute
performance").  TPU absolute bounds come from the dry-run roofline instead.
"""
from __future__ import annotations

import time
from typing import Callable

import jax


def time_callable(fn: Callable, *args, warmup: int = 1, iters: int = 3,
                  **kwargs) -> float:
    """Median wall seconds of fn(*args) after warmup (jit-compile excluded)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kwargs))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kwargs))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def csv_row(name: str, seconds: float, derived: str) -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"


def solver_metric(iters: int, s_per_iter: float, *, mode: str = "fixed",
                  **extra) -> dict:
    """One row of BENCH_stencil.json's ``solver`` section (stable schema:
    every row has mode/iters/s_per_iter; converged rows add
    backend/residual/converged)."""
    return {"mode": mode, "iters": int(iters),
            "s_per_iter": float(s_per_iter), **extra}
