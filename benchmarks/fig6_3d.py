"""Paper Fig 6: 3D Jacobi (X=64, Y=64, Z=10) with non-zero boundary
conditions — the channels-trick Conv2D encoding (the only 3D path the CS-1
stack supported) vs the native Conv3D and direct-stencil paths the paper
could not use.  Quantifies the Z²-banded channel matrix overhead.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    DeliveredPerf,
    DirichletBC,
    conv_jacobi_3d_channels,
    conv_jacobi_3d_native,
    encoding_flops_per_point,
    laplace_jacobi,
)
from repro.kernels import jacobi3d
from benchmarks.common import csv_row, time_callable

GRID = (10, 64, 64)  # (Z, X, Y) — the largest supported shape on the CS-1


def run(steps: int = 4, iters: int = 50, kernel_iters: int = 5):
    spec = laplace_jacobi(3)
    bc = DirichletBC(1.0)
    n = GRID[0] * GRID[1] * GRID[2]
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((steps, *GRID)), jnp.float32)
    rows = []

    f_ch = jax.jit(lambda xx: conv_jacobi_3d_channels(xx, spec, bc, iters))
    sec = time_callable(f_ch, x)
    perf = DeliveredPerf(n * steps,
                         encoding_flops_per_point(spec, "conv3d_channels",
                                                  n_total=GRID[0]),
                         13, iters, sec)
    rows.append(csv_row("fig6/conv2d-channels", sec,
                        f"{perf.delivered_gflops:.2f} delivered GFLOPS | "
                        f"waste x{perf.waste_ratio:.1f} (Z-banded matrix)"))

    f_nat = jax.jit(lambda xx: conv_jacobi_3d_native(xx, spec, bc, iters))
    sec = time_callable(f_nat, x)
    perf = DeliveredPerf(n * steps, encoding_flops_per_point(spec, "conv"),
                         13, iters, sec)
    rows.append(csv_row("fig6/native-conv3d", sec,
                        f"{perf.delivered_gflops:.2f} delivered GFLOPS | "
                        f"waste x{perf.waste_ratio:.1f}"))

    f_k = lambda xx: jacobi3d(xx, spec, bc_value=1.0, iterations=kernel_iters,
                              block_x=32)
    sec = time_callable(f_k, x, warmup=1, iters=1)
    perf = DeliveredPerf(n * steps, encoding_flops_per_point(spec, "direct"),
                         13, kernel_iters, sec)
    rows.append(csv_row("fig6/pallas-direct(interp)", sec,
                        f"{perf.delivered_gflops:.3f} delivered GFLOPS | "
                        f"waste x{perf.waste_ratio:.2f} (interpret mode)"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
