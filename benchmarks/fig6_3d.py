"""Paper Fig 6: 3D Jacobi (X=64, Y=64, Z=10) with non-zero boundary
conditions — the channels-trick Conv2D encoding (the only 3D path the CS-1
stack supported) vs the native Conv3D and direct-stencil paths the paper
could not use.  Quantifies the Z²-banded channel matrix overhead.

All paths dispatch through the unified solver engine (core/solver.py); the
run-to-convergence section reports iterations and seconds per iteration for
the 3D problem.  ``run`` returns (csv rows, solver-metrics dict).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import (
    DeliveredPerf,
    Solver,
    encoding_flops_per_point,
    laplace_jacobi,
)
from benchmarks.common import csv_row, solver_metric, time_callable

GRID = (10, 64, 64)  # (Z, X, Y) — the largest supported shape on the CS-1


def run(steps: int = 4, iters: int = 50, kernel_iters: int = 5,
        solve_rtol: float = 1e-6, solve_max_iters: int = 10_000):
    spec = laplace_jacobi(3)
    n = GRID[0] * GRID[1] * GRID[2]
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((steps, *GRID)), jnp.float32)
    rows = []
    metrics: dict[str, dict] = {}

    def fixed(backend, n_iters):
        return Solver(spec, GRID, backend=backend, bc=1.0, rtol=None,
                      atol=None, max_iters=n_iters)

    s_ch = fixed("conv", iters)
    sec = time_callable(s_ch.plan, x)
    perf = DeliveredPerf(n * steps,
                         encoding_flops_per_point(spec, "conv3d_channels",
                                                  n_total=GRID[0]),
                         13, iters, sec)
    rows.append(csv_row("fig6/conv2d-channels", sec,
                        f"{perf.delivered_gflops:.2f} delivered GFLOPS | "
                        f"waste x{perf.waste_ratio:.1f} (Z-banded matrix)"))
    metrics["fig6/conv2d-channels"] = solver_metric(iters, sec / iters)

    s_nat = fixed("conv3d_native", iters)
    sec = time_callable(s_nat.plan, x)
    perf = DeliveredPerf(n * steps, encoding_flops_per_point(spec, "conv"),
                         13, iters, sec)
    rows.append(csv_row("fig6/native-conv3d", sec,
                        f"{perf.delivered_gflops:.2f} delivered GFLOPS | "
                        f"waste x{perf.waste_ratio:.1f}"))
    metrics["fig6/native-conv3d"] = solver_metric(iters, sec / iters)

    s_k = fixed("pallas", kernel_iters)
    sec = time_callable(s_k.plan, x, warmup=1, iters=1)
    perf = DeliveredPerf(n * steps, encoding_flops_per_point(spec, "direct"),
                         13, kernel_iters, sec)
    rows.append(csv_row("fig6/pallas-direct(interp)", sec,
                        f"{perf.delivered_gflops:.3f} delivered GFLOPS | "
                        f"waste x{perf.waste_ratio:.2f} (interpret mode)"))
    metrics["fig6/pallas-direct(interp)"] = solver_metric(
        kernel_iters, sec / kernel_iters)

    # run-to-convergence on the Fig 6 problem (hot walls, cold interior)
    s = Solver(spec, GRID, backend="conv3d_native", bc=1.0, rtol=solve_rtol,
               check_every=20, max_iters=solve_max_iters)
    x0 = jnp.zeros(GRID, jnp.float32)
    s.solve(x0)                 # compile outside the reported wall time
    res = s.solve(x0)
    spi = res.wall_seconds / max(res.iterations, 1)
    rows.append(csv_row("fig6/solve/conv3d_native", res.wall_seconds,
                        f"iters={res.iterations} s/iter={spi:.2e} "
                        f"residual={res.residual:.1e} converged={res.converged}"))
    metrics["fig6/solve/conv3d_native"] = solver_metric(
        res.iterations, spi, mode="converged", backend=res.backend,
        residual=float(res.residual), converged=bool(res.converged))
    return rows, metrics


if __name__ == "__main__":
    for r in run()[0]:
        print(r)
