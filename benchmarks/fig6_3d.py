"""Paper Fig 6: 3D Jacobi (X=64, Y=64, Z=10) with non-zero boundary
conditions — the channels-trick Conv2D encoding (the only 3D path the CS-1
stack supported) vs the native Conv3D and direct-stencil paths the paper
could not use.  Quantifies the Z²-banded channel matrix overhead.

All paths dispatch through the unified ``make_plan`` API (core/plan.py).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import (
    DeliveredPerf,
    encoding_flops_per_point,
    laplace_jacobi,
    make_plan,
)
from benchmarks.common import csv_row, time_callable

GRID = (10, 64, 64)  # (Z, X, Y) — the largest supported shape on the CS-1


def run(steps: int = 4, iters: int = 50, kernel_iters: int = 5):
    spec = laplace_jacobi(3)
    n = GRID[0] * GRID[1] * GRID[2]
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((steps, *GRID)), jnp.float32)
    rows = []

    p_ch = make_plan(spec, GRID, backend="conv", bc=1.0, iters=iters)
    sec = time_callable(p_ch, x)
    perf = DeliveredPerf(n * steps,
                         encoding_flops_per_point(spec, "conv3d_channels",
                                                  n_total=GRID[0]),
                         13, iters, sec)
    rows.append(csv_row("fig6/conv2d-channels", sec,
                        f"{perf.delivered_gflops:.2f} delivered GFLOPS | "
                        f"waste x{perf.waste_ratio:.1f} (Z-banded matrix)"))

    p_nat = make_plan(spec, GRID, backend="conv3d_native", bc=1.0, iters=iters)
    sec = time_callable(p_nat, x)
    perf = DeliveredPerf(n * steps, encoding_flops_per_point(spec, "conv"),
                         13, iters, sec)
    rows.append(csv_row("fig6/native-conv3d", sec,
                        f"{perf.delivered_gflops:.2f} delivered GFLOPS | "
                        f"waste x{perf.waste_ratio:.1f}"))

    p_k = make_plan(spec, GRID, backend="pallas", bc=1.0, iters=kernel_iters)
    sec = time_callable(p_k, x, warmup=1, iters=1)
    perf = DeliveredPerf(n * steps, encoding_flops_per_point(spec, "direct"),
                         13, kernel_iters, sec)
    rows.append(csv_row("fig6/pallas-direct(interp)", sec,
                        f"{perf.delivered_gflops:.3f} delivered GFLOPS | "
                        f"waste x{perf.waste_ratio:.2f} (interpret mode)"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
