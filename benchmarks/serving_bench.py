"""Serving benchmark: solves/sec and latency through the plan cache + engine.

The serving tier's claim is that the compiled solver loop is the expensive
artifact and everything else should amortize it.  This benchmark measures
that on the Table-1 convergence workload (2D Laplace Jacobi, 64x64 grid,
bc=1.0, rtol=1e-6, check_every=20 — the paper's run-to-convergence case)
with per-request random initial fields and small per-request source terms,
three ways:

  cold-serial      one fresh one-shot ``solve()`` per request — every
                   request pays plan building + jit compilation (the
                   pre-serving baseline);
  warm-serial      sequential requests through a primed ``PlanCache`` —
                   compilation amortized, no batching;
  warm-coalesced   concurrent requests through ``ServingEngine`` — one
                   batched dispatch serves the whole group, per-instance
                   convergence freezing keeps results exact.

plus a pad-to-bucket row: a 60x60 request served by the warm 64x64-bucket
entry with no new compilation.

Rows land in BENCH_stencil.json's schema-7 ``serving`` section (keys
``serving/...``) with solves/sec, p50/p99 latency at the fixed residual
target, cache hit-rate, and a ``speedup`` row recording the acceptance bar:
warm-coalesced throughput >= 5x cold-serial.

  PYTHONPATH=src python -m benchmarks.serving_bench [--smoke] [--json PATH]
  PYTHONPATH=src python -m benchmarks.serving_bench --validate PATH
"""
from __future__ import annotations

import asyncio
import time

import numpy as np

from benchmarks.common import csv_row

GRID = (64, 64)
NEAR_MISS_GRID = (60, 60)
BC = 1.0
RTOL = 1e-6
CHECK_EVERY = 20
MAX_ITERS = 20_000
SPEEDUP_TARGET = 5.0


def _problems(n: int, grid, seed: int = 0):
    """n (x0, source) pairs: random interior, shell at BC, small sources."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        x0 = rng.standard_normal(grid).astype(np.float32)
        for d in range(len(grid)):
            idx = [slice(None)] * len(grid)
            for edge in (0, -1):
                idx[d] = edge
                x0[tuple(idx)] = BC
        src = (rng.standard_normal(grid) * 1e-3).astype(np.float32)
        out.append((x0, src))
    return out


def _percentiles(latencies: list[float]) -> tuple[float, float]:
    ls = sorted(latencies)
    p50 = ls[len(ls) // 2]
    p99 = ls[min(len(ls) - 1, int(np.ceil(0.99 * len(ls))) - 1)]
    return p50, p99


def _row(name: str, latencies: list[float], wall: float, **extra) -> dict:
    p50, p99 = _percentiles(latencies)
    return {"requests": len(latencies),
            "solves_per_sec": len(latencies) / wall,
            "p50_ms": p50 * 1e3, "p99_ms": p99 * 1e3,
            "grid": list(GRID), "rtol": RTOL, **extra}


def _cold_serial(problems) -> dict:
    from repro.core.solver import solve
    from repro.core.stencil import laplace_jacobi
    lat = []
    iters = []
    for x0, src in problems:
        # a fresh Solver per request: plan build + compile every time
        t0 = time.perf_counter()
        res = solve(laplace_jacobi(2), x0, bc=BC, rtol=RTOL,
                    check_every=CHECK_EVERY, max_iters=MAX_ITERS, source=src)
        lat.append(time.perf_counter() - t0)
        iters.append(res.iterations)
        assert res.converged
    return _row("cold-serial", lat, sum(lat), cached=False, coalesced=False,
                iters_mean=float(np.mean(iters)))


def _warm_serial(cache, problems) -> dict:
    from repro.core.stencil import laplace_jacobi
    spec = laplace_jacobi(2)
    kw = dict(bc=BC, rtol=RTOL, check_every=CHECK_EVERY, max_iters=MAX_ITERS)
    # prime: compile the bucket entry + the operand signature once
    cache.solve(spec, problems[0][0], source=problems[0][1], **kw)
    lat = []
    for x0, src in problems:
        t0 = time.perf_counter()
        res = cache.solve(spec, x0, source=src, **kw)
        lat.append(time.perf_counter() - t0)
        assert res.converged
    return _row("warm-serial", lat, sum(lat), cached=True, coalesced=False,
                backend=res.backend,
                cache_hit_rate=cache.stats.hit_rate)


async def _coalesced(engine, spec, problems):
    t_all = time.perf_counter()

    async def one(x0, src):
        t0 = time.perf_counter()
        res = await engine.submit(
            spec, x0, bc=BC, source=src, rtol=RTOL,
            check_every=CHECK_EVERY, max_iters=MAX_ITERS)
        return time.perf_counter() - t0, res

    out = await asyncio.gather(*(one(x0, src) for x0, src in problems))
    wall = time.perf_counter() - t_all
    return out, wall


def _warm_coalesced(cache, problems) -> dict:
    from repro.core.stencil import laplace_jacobi
    from repro.serve import ServingEngine

    async def main():
        eng = ServingEngine(cache, max_batch=len(problems), max_wait=0.05,
                            max_queue=4 * len(problems))
        async with eng:
            # prime the batched loop signature (warm means warm)
            await _coalesced(eng, laplace_jacobi(2),
                             _problems(len(problems), GRID, seed=11))
            out, wall = await _coalesced(eng, laplace_jacobi(2), problems)
        return eng, out, wall

    eng, out, wall = asyncio.run(main())
    lat = [t for t, _ in out]
    assert all(r.converged for _, r in out)
    return _row("warm-coalesced", lat, wall, cached=True, coalesced=True,
                backend=out[0][1].backend, batches=eng.stats.batches,
                mean_batch=eng.stats.mean_batch,
                cache_hit_rate=cache.stats.hit_rate)


def _near_miss(cache, n: int) -> dict:
    from repro.core.stencil import laplace_jacobi
    spec = laplace_jacobi(2)
    compile_before = cache.stats.compile_seconds
    lat = []
    for x0, src in _problems(n, NEAR_MISS_GRID, seed=7):
        t0 = time.perf_counter()
        res = cache.solve(spec, x0, source=src, bc=BC, rtol=RTOL,
                          check_every=CHECK_EVERY, max_iters=MAX_ITERS)
        lat.append(time.perf_counter() - t0)
        assert res.converged
    row = _row("pad-to-bucket", lat, sum(lat), cached=True, coalesced=False)
    row.update(grid=list(NEAR_MISS_GRID), bucket=list(GRID),
               cache_hit=cache.stats.compile_seconds == compile_before,
               cache_hit_rate=cache.stats.hit_rate)
    return row


def run(smoke: bool = False) -> tuple[list[str], dict]:
    """(CSV rows, ``serving/...`` metrics) for the benchmark runner."""
    from repro.core.plan_cache import PlanCache

    n_cold = 2 if smoke else 3
    n_warm = 4 if smoke else 6
    n_coal = 8 if smoke else 16

    cache = PlanCache(capacity=16)
    cold = _cold_serial(_problems(n_cold, GRID, seed=1))
    warm = _warm_serial(cache, _problems(n_warm, GRID, seed=2))
    coal = _warm_coalesced(cache, _problems(n_coal, GRID, seed=3))
    near = _near_miss(cache, 2)

    speedup = {
        "coalesced_vs_cold": coal["solves_per_sec"] / cold["solves_per_sec"],
        "warm_serial_vs_cold": (warm["solves_per_sec"]
                                / cold["solves_per_sec"]),
        "target": SPEEDUP_TARGET,
    }
    speedup["pass"] = speedup["coalesced_vs_cold"] >= SPEEDUP_TARGET
    cache_row = cache.stats.as_dict()
    cache_row["entries"] = len(cache)

    prefix = "serving/table1-64x64"
    metrics = {
        f"{prefix}/cold-serial": cold,
        f"{prefix}/warm-serial": warm,
        f"{prefix}/warm-coalesced": coal,
        "serving/table1-60x60/pad-to-bucket": near,
        f"{prefix}/speedup": speedup,
        f"{prefix}/cache": cache_row,
    }
    rows = [
        csv_row(f"serving-{r['requests']}x-{name}",
                1.0 / r["solves_per_sec"],
                f"{r['solves_per_sec']:.2f}/s p50={r['p50_ms']:.0f}ms "
                f"p99={r['p99_ms']:.0f}ms")
        for name, r in (("cold-serial", cold), ("warm-serial", warm),
                        ("warm-coalesced", coal), ("pad-to-bucket", near))
    ]
    rows.append(csv_row(
        "serving-speedup", 0.0,
        f"coalesced {speedup['coalesced_vs_cold']:.1f}x vs cold (target "
        f"{SPEEDUP_TARGET:.0f}x: {'PASS' if speedup['pass'] else 'FAIL'})"))
    return rows, metrics


def validate_serving(data: dict) -> list[str]:
    """Errors in an artifact's ``serving`` section; [] means valid.

    Accepts a full BENCH_stencil.json (schema 7) or the mini artifact
    ``--json`` writes.  Enforces the acceptance bar (warm-coalesced >= 5x
    cold-serial solves/sec) and rejects empty-dict benchmark sections
    anywhere in the payload (a silently-skipped section must be omitted,
    not recorded as ``{}``).
    """
    errors: list[str] = []
    if "schema" in data and data["schema"] not in (7,):
        errors.append(f"schema {data['schema']!r} != 7")
    for section, content in data.items():
        if isinstance(content, dict) and not content:
            errors.append(f"empty-dict section {section!r} (omit instead)")
    sv = data.get("serving")
    if not isinstance(sv, dict) or not sv:
        return errors + ["missing or empty 'serving' section"]
    for kind in ("cold-serial", "warm-serial", "warm-coalesced"):
        rows = [m for k, m in sv.items() if k.endswith("/" + kind)]
        if not rows:
            errors.append(f"no serving/*/{kind} row")
            continue
        for m in rows:
            for field in ("solves_per_sec", "p50_ms", "p99_ms", "requests"):
                if not (isinstance(m.get(field), (int, float))
                        and m[field] > 0):
                    errors.append(f"{kind}: missing/non-positive {field!r}")
            if kind != "cold-serial" and "cache_hit_rate" not in m:
                errors.append(f"{kind}: missing cache_hit_rate")
    speed = [m for k, m in sv.items() if k.endswith("/speedup")]
    if not speed:
        errors.append("no serving/*/speedup row")
    for m in speed:
        if m.get("pass") is not True:
            errors.append(
                f"speedup acceptance failed: coalesced_vs_cold="
                f"{m.get('coalesced_vs_cold')} < target {m.get('target')}")
    return errors


def main(argv=None) -> int:
    import argparse
    import json
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="fewer requests (CI tier)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write a mini artifact {schema, serving}")
    ap.add_argument("--validate", default=None, metavar="PATH",
                    help="validate an artifact's serving section and exit")
    args = ap.parse_args(argv)

    if args.validate:
        with open(args.validate) as f:
            errors = validate_serving(json.load(f))
        for e in errors:
            print(f"INVALID: {e}")
        print(f"{args.validate}: serving section "
              f"{'INVALID' if errors else 'OK'}")
        return 1 if errors else 0

    rows, metrics = run(smoke=args.smoke)
    print("name,us_per_call,derived")
    for row in rows:
        print(row, flush=True)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"schema": 7, "serving": metrics}, f, indent=2,
                      sort_keys=True)
        print(f"# wrote {len(metrics)} serving rows to {args.json}")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
