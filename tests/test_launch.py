"""Launch-path integration tests: the dry-run pipeline end-to-end on reduced
configs (subprocess, fake devices) and the training CLI with failure
injection + restart."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}


def _run(cmd, timeout=900):
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout,
                       env=ENV, cwd=REPO)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    return r.stdout


class TestDryrunPipeline:
    @pytest.mark.parametrize("shape", ["train_4k", "decode_32k"])
    def test_smoke_cell_compiles_multipod(self, shape, tmp_path):
        out = _run([sys.executable, "-m", "repro.launch.dryrun",
                    "--arch", "qwen3-0.6b", "--shape", shape,
                    "--mesh", "multipod", "--smoke", "--out", str(tmp_path)])
        assert "status=OK" in out
        path = os.path.join(str(tmp_path),
                            f"qwen3-0.6b__{shape}__pod2x16x16.json")
        rec = json.load(open(path))
        assert rec["n_devices"] == 512
        assert rec["hlo_cost"]["flops"] > 0
        assert rec["memory_analysis"]["temp_size_in_bytes"] > 0

    def test_skip_cell_records_reason(self, tmp_path):
        out = _run([sys.executable, "-m", "repro.launch.dryrun",
                    "--arch", "glm4-9b", "--shape", "long_500k",
                    "--mesh", "pod", "--smoke", "--out", str(tmp_path)])
        assert "status=SKIP" in out
        rec = json.load(open(os.path.join(
            str(tmp_path), "glm4-9b__long_500k__pod16x16.json")))
        assert "full-attention" in rec["skip_reason"]

    def test_ssm_long_context_compiles(self, tmp_path):
        out = _run([sys.executable, "-m", "repro.launch.dryrun",
                    "--arch", "mamba2-370m", "--shape", "long_500k",
                    "--mesh", "pod", "--smoke", "--out", str(tmp_path)])
        assert "status=OK" in out


class TestTrainCLI:
    def test_loss_descends_and_restart_matches(self, tmp_path):
        ck = str(tmp_path / "ck")
        base = [sys.executable, "-m", "repro.launch.train",
                "--arch", "qwen3-0.6b", "--smoke", "--steps", "8",
                "--global-batch", "4", "--seq-len", "32",
                "--checkpoint-dir", ck, "--checkpoint-every", "4"]
        # fail mid-run, then restart
        r = subprocess.run(base + ["--fail-at-step", "6"], capture_output=True,
                           text=True, env=ENV, cwd=REPO, timeout=900)
        assert r.returncode != 0 and "InjectedFailure" in r.stderr
        out = _run(base)
        final_restarted = out.strip().splitlines()[-1]

        # uninterrupted reference run
        ref_cmd = [str(tmp_path / "ck2") if a == ck else a for a in base]
        out_ref = _run(ref_cmd)
        final_ref = out_ref.strip().splitlines()[-1]
        assert final_restarted.split("->")[-1] == final_ref.split("->")[-1]
        assert "done:" in final_ref
