"""Model zoo: per-arch smoke tests (reduced configs, one forward/train step on
CPU, shape + finiteness asserts) and decode-consistency checks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models.model_zoo import build
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step

RNG = np.random.default_rng(3)


def _batch(cfg, B, S):
    batch = {
        "tokens": jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, S))),
        "labels": jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, S))),
    }
    if cfg.family == "encdec":
        batch["enc_frames"] = jnp.asarray(
            RNG.standard_normal((B, cfg.enc_len, cfg.d_model)), jnp.float32)
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.asarray(
            RNG.standard_normal((B, cfg.n_vision_tokens, cfg.d_model)),
            jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(S), (B, S))
        batch["positions"] = jnp.broadcast_to(pos, (3, B, S))
    return batch


@pytest.mark.parametrize("arch", list_archs())
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = get_config(arch, smoke=True)
        api = build(cfg)
        params = api.init(jax.random.PRNGKey(0), jnp.float32)
        B, S = 2, 16
        hidden, aux = api.forward(params, _batch(cfg, B, S))
        assert hidden.shape == (B, S, cfg.d_model)
        assert bool(jnp.all(jnp.isfinite(hidden)))
        assert bool(jnp.isfinite(aux))

    def test_one_train_step(self, arch):
        cfg = get_config(arch, smoke=True)
        api = build(cfg)
        state = init_train_state(api, jax.random.PRNGKey(0))
        step = make_train_step(api, None, AdamWConfig(total_steps=10,
                                                      warmup_steps=2))
        new_state, metrics = step(state, _batch(cfg, 2, 16))
        assert bool(jnp.isfinite(metrics["loss"]))
        assert int(new_state["step"]) == 1
        # params actually changed
        d0 = jax.tree.leaves(state["params"])[0]
        d1 = jax.tree.leaves(new_state["params"])[0]
        assert not np.allclose(d0, d1)


@pytest.mark.parametrize("arch", list_archs())
def test_decode_matches_forward(arch):
    """prefill(S tokens) + decode = forward(S+1 tokens) at the last position."""
    cfg = get_config(arch, smoke=True)
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(1), jnp.float32)
    B, S = 2, 12
    batch = _batch(cfg, B, S + 1)
    full = dict(batch)
    prefix = dict(batch, tokens=batch["tokens"][:, :S])
    if "positions" in batch:
        prefix["positions"] = batch["positions"][..., :S]

    # full forward logits at position S (predicting token S+1)
    from repro.models.layers import rms_norm
    if cfg.family == "encdec":
        from repro.models import encdec as E
        enc = E.encode(cfg, params, batch["enc_frames"])
        hidden = E.decode_train(cfg, params, batch["tokens"], enc)
    else:
        from repro.models import transformer as T
        hidden, _ = T.forward(cfg, params, batch["tokens"],
                              positions=batch.get("positions"),
                              vision_embeds=batch.get("vision_embeds"))
    logits_full = jnp.einsum("bd,vd->bv", hidden[:, S], params["lm_head"])

    # prefill S tokens then decode token S
    _, cache = api.prefill(params, prefix, max_len=S + 4)
    logits_dec, _ = api.decode_step(params, batch["tokens"][:, S], cache, S)

    np.testing.assert_allclose(np.asarray(logits_dec), np.asarray(logits_full),
                               rtol=2e-2, atol=2e-2)


def test_remat_group_grad_equivalence():
    cfg = dataclasses.replace(get_config("qwen3-0.6b", smoke=True), n_layers=4)
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0), jnp.float32)
    batch = _batch(cfg, 2, 16)
    from repro.train.train_step import loss_fn

    def grad_with(rg):
        c = dataclasses.replace(cfg, remat_group=rg)
        a = build(c)
        return jax.value_and_grad(lambda p: loss_fn(a, p, batch, None)[0])(params)

    (l1, g1), (l2, g2) = grad_with(1), grad_with(2)
    assert float(l1) == pytest.approx(float(l2), abs=1e-6)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(a, b, atol=1e-6)


def test_param_counts_close_to_nameplate():
    # full configs should land near their nameplate sizes
    expect = {
        "nemotron-4-15b": (15e9, 0.35),
        "glm4-9b": (9e9, 0.35),
        "qwen2-vl-2b": (2e9, 0.45),
        "phi3-medium-14b": (14e9, 0.35),
        "zamba2-1.2b": (1.2e9, 0.45),
        "mamba2-370m": (370e6, 0.45),
        "qwen3-moe-30b-a3b": (30e9, 0.35),
        # the assignment's 48L x 64e x 1408 arithmetic gives 28.9B, not the
        # 16B nameplate (real Moonlight is 27 layers); we follow the
        # assignment numbers exactly — see DESIGN §Arch-applicability.
        "moonshot-v1-16b-a3b": (28.9e9, 0.1),
    }
    for arch, (target, tol) in expect.items():
        n = get_config(arch).param_count()
        assert abs(n - target) / target < tol, f"{arch}: {n:.3e} vs {target:.3e}"


def test_moe_capacity_drops_tokens_gracefully():
    from repro.models.moe import moe_apply, moe_table
    from repro.models.layers import init_params
    D, E = 32, 8
    params = init_params(moe_table(D, E, 64), jax.random.PRNGKey(0), jnp.float32)
    x = jnp.asarray(RNG.standard_normal((2, 64, D)), jnp.float32)
    out, aux = moe_apply(params, x, top_k=2, capacity_factor=0.5,
                         group_size=64)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))


def test_mrope_reduces_to_rope_for_text():
    from repro.models.layers import apply_mrope, apply_rope
    B, S, H, hd = 2, 8, 2, 16
    x = jnp.asarray(RNG.standard_normal((B, S, H, hd)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    mpos = jnp.broadcast_to(pos, (3, B, S))
    a = apply_rope(x, pos, 1e4)
    b = apply_mrope(x, mpos, 1e4, (3, 3, 2))
    np.testing.assert_allclose(a, b, atol=1e-6)
