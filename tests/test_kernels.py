"""Per-kernel allclose sweeps vs the ref.py oracles (interpret mode on CPU),
with shape/dtype sweeps and hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip; the rest of the module still runs
    from _hypothesis_stub import given, settings, st

from repro.core import DirichletBC, build_dense_matrix, laplace_jacobi, star
from repro.core.reference import jacobi_reference
from repro.kernels import (
    dense_jacobi_kernel,
    dense_stencil_matmul,
    jacobi2d,
    jacobi3d,
    stencil2d,
    stencil3d,
)
from repro.kernels.ref import (
    dense_stencil_ref,
    jacobi2d_ref,
    stencil2d_ref,
    stencil3d_ref,
)

RNG = np.random.default_rng(7)


class TestStencil2D:
    @pytest.mark.parametrize("shape", [(1, 8, 8), (2, 17, 33), (1, 64, 64),
                                       (3, 9, 200), (1, 300, 40)])
    def test_raw_shapes(self, shape):
        spec = laplace_jacobi(2)
        x = jnp.asarray(RNG.standard_normal(shape), jnp.float32)
        np.testing.assert_allclose(stencil2d(x, spec, block_h=8),
                                   stencil2d_ref(x, spec), atol=1e-6)

    @pytest.mark.parametrize("dtype,atol", [(jnp.float32, 1e-6),
                                            (jnp.bfloat16, 3e-2)])
    def test_dtypes(self, dtype, atol):
        spec = laplace_jacobi(2)
        x = jnp.asarray(RNG.standard_normal((2, 32, 48)), dtype)
        out = stencil2d(x, spec, block_h=8)
        ref = stencil2d_ref(x.astype(jnp.float32), spec)
        np.testing.assert_allclose(out.astype(jnp.float32), ref, atol=atol)

    def test_radius2(self):
        spec = star(2, [0.1, 0.05], center=0.4)
        x = jnp.asarray(RNG.standard_normal((2, 20, 40)), jnp.float32)
        np.testing.assert_allclose(stencil2d(x, spec, block_h=8),
                                   stencil2d_ref(x, spec), atol=1e-6)

    def test_fused_bc(self):
        spec = laplace_jacobi(2)
        bc = DirichletBC(2.0)
        x = jnp.asarray(RNG.standard_normal((2, 24, 16)), jnp.float32)
        xb = jnp.stack([bc.set_boundary(x[i]) for i in range(2)])
        out = stencil2d(xb, spec, block_h=8, bc_value=2.0)
        np.testing.assert_allclose(out, jacobi2d_ref(x, spec, 2.0, 1), atol=1e-6)

    @settings(max_examples=20, deadline=None)
    @given(h=st.integers(3, 40), w=st.integers(3, 40),
           bh=st.sampled_from([8, 16]), bc=st.floats(-3, 3))
    def test_property_any_shape(self, h, w, bh, bc):
        spec = laplace_jacobi(2)
        x = jnp.asarray(np.random.default_rng(h * 41 + w)
                        .standard_normal((1, h, w)), jnp.float32)
        out = jacobi2d(x, spec, bc_value=bc, iterations=2, block_h=bh)
        ref = jacobi2d_ref(x, spec, bc, 2)
        np.testing.assert_allclose(out, ref, atol=1e-5)


class TestJacobiFused:
    @pytest.mark.parametrize("fuse", [1, 2, 4, 8])
    def test_fused_equals_sequential(self, fuse):
        spec = laplace_jacobi(2)
        x = jnp.asarray(RNG.standard_normal((2, 24, 40)), jnp.float32)
        out = jacobi2d(x, spec, bc_value=1.0, iterations=8, fuse=fuse, block_h=8)
        ref = jacobi2d_ref(x, spec, 1.0, 8)
        np.testing.assert_allclose(out, ref, atol=1e-5)

    def test_fuse_must_divide(self):
        spec = laplace_jacobi(2)
        x = jnp.zeros((1, 8, 8), jnp.float32)
        with pytest.raises(ValueError):
            jacobi2d(x, spec, bc_value=0.0, iterations=7, fuse=2)


class TestStencil3D:
    @pytest.mark.parametrize("shape", [(1, 10, 16, 20), (2, 4, 9, 7),
                                       (1, 10, 64, 64)])
    def test_raw(self, shape):
        spec = laplace_jacobi(3)
        x = jnp.asarray(RNG.standard_normal(shape), jnp.float32)
        np.testing.assert_allclose(stencil3d(x, spec, block_x=8),
                                   stencil3d_ref(x, spec), atol=1e-6)

    def test_jacobi3d_bc(self):
        spec = laplace_jacobi(3)
        bc = DirichletBC(0.5)
        x = jnp.asarray(RNG.standard_normal((1, 10, 16, 20)), jnp.float32)
        out = jacobi3d(x, spec, bc_value=0.5, iterations=3, block_x=8)
        ref = jnp.stack([jacobi_reference(x[i], spec, bc, 3) for i in range(1)])
        np.testing.assert_allclose(out, ref, atol=1e-5)


class TestDenseStencilMatmul:
    @pytest.mark.parametrize("s,n", [(1, 64), (8, 130), (32, 96)])
    def test_matmul_shapes(self, s, n):
        x = jnp.asarray(RNG.standard_normal((s, n)), jnp.float32)
        w = jnp.asarray(RNG.standard_normal((n, n)), jnp.float32)
        out = dense_stencil_matmul(x, w, bm=8, bk=128, bn=128)
        np.testing.assert_allclose(out, dense_stencil_ref(x, w), rtol=1e-4,
                                   atol=1e-4)

    def test_full_dense_jacobi(self):
        spec = laplace_jacobi(2)
        bc = DirichletBC(1.0)
        x0 = jnp.asarray(RNG.standard_normal((2, 12, 10)), jnp.float32)
        m = jnp.asarray(build_dense_matrix((12, 10), spec), jnp.float32)
        x0b = jnp.stack([bc.set_boundary(x0[i]) for i in range(2)])
        out = dense_jacobi_kernel(x0b, m, iterations=4, bm=8, bk=128, bn=128)
        ref = jnp.stack([jacobi_reference(x0[i], spec, bc, 4) for i in range(2)])
        np.testing.assert_allclose(out, ref, atol=1e-4)

    def test_bf16_accumulates_fp32(self):
        x = jnp.asarray(RNG.standard_normal((8, 256)), jnp.bfloat16)
        w = jnp.asarray(RNG.standard_normal((256, 256)), jnp.bfloat16)
        out = dense_stencil_matmul(x, w, bm=8, bk=128, bn=128)
        ref = dense_stencil_ref(x, w)
        np.testing.assert_allclose(out.astype(np.float32),
                                   ref.astype(np.float32), rtol=3e-2, atol=3e-1)


class TestEncodingAgreement:
    """All four implementations of the same operator agree (paper's core claim:
    the encodings compute the same stencil)."""

    def test_all_encodings_agree_2d(self):
        from repro.core import conv_jacobi_2d, dense_jacobi_with_bc
        spec = laplace_jacobi(2)
        bc = DirichletBC(1.7)
        x = jnp.asarray(RNG.standard_normal((1, 16, 16)), jnp.float32)
        iters = 4
        a = dense_jacobi_with_bc(x, spec, bc, iters)
        b = conv_jacobi_2d(x, spec, bc, iters)
        c = jacobi2d(x, spec, bc_value=1.7, iterations=iters, block_h=8)
        d = jacobi2d(x, spec, bc_value=1.7, iterations=iters, fuse=2, block_h=8)
        np.testing.assert_allclose(a, b, atol=1e-5)
        np.testing.assert_allclose(b, c, atol=1e-5)
        np.testing.assert_allclose(c, d, atol=1e-5)
