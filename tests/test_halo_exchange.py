"""Direct unit tests for parallel/halo.py and the deep-halo accounting.

The exchange primitives were previously covered only indirectly through the
distributed solver; these tests pin their contracts down: ``exchange_1d``
halo extents and non-wrapping zero edges at radius >= 2, the corner-transit
property of the two-phase ``exchange_halo_2d`` (the augmented tile equals a
window of the zero-padded global grid, diagonal-neighbour values included),
and the depth guard.  Subprocess cases use the ``run_with_devices`` fixture
(8 forced host devices); the analytic accounting and runner validation run
in-process on the 1x1 mesh.
"""
import jax
import pytest

from repro.core.distributed import (
    HALO_PHASES_PER_EXCHANGE,
    halo_comm_rounds,
    make_halo_runner,
    max_halo_fuse,
)
from repro.core.stencil import laplace_jacobi, star


class TestCommAccounting:
    def test_rounds_drop_by_fuse_depth(self):
        assert halo_comm_rounds(16, 1) == 16 * HALO_PHASES_PER_EXCHANGE
        assert halo_comm_rounds(16, 2) == 8 * HALO_PHASES_PER_EXCHANGE
        assert halo_comm_rounds(16, 4) == 4 * HALO_PHASES_PER_EXCHANGE
        assert halo_comm_rounds(16, 16) == HALO_PHASES_PER_EXCHANGE

    def test_partial_chunk_rounds_up(self):
        # 5 iterations at fuse 2 still need 3 exchanges.
        assert halo_comm_rounds(5, 2) == 3 * HALO_PHASES_PER_EXCHANGE

    def test_variable_specs_pay_one_field_exchange(self):
        assert (halo_comm_rounds(8, 2, variable=True)
                == halo_comm_rounds(8, 2) + HALO_PHASES_PER_EXCHANGE)

    def test_max_fuse_bounded_by_local_tile(self):
        assert max_halo_fuse(1, 8, 8) == 8
        assert max_halo_fuse(2, 8, 8) == 4
        assert max_halo_fuse(1, 8, 6) == 6
        # degenerate tiles still allow the unfused schedule
        assert max_halo_fuse(3, 2, 2) == 1

    def test_exchange_bytes_scale_with_perimeter(self):
        from repro.kernels.tiling import halo_exchange_bytes
        b1 = halo_exchange_bytes((64, 64), 1, 1)
        b2 = halo_exchange_bytes((128, 128), 1, 1)
        assert b1 == 2 * 1 * (64 + 64 + 2) * 4
        # doubling the tile edge roughly doubles (not quadruples) the bytes
        assert 1.9 < b2 / b1 < 2.1
        # deeper halos move proportionally more per exchange
        assert halo_exchange_bytes((64, 64), 4, 1) > \
            3 * halo_exchange_bytes((64, 64), 1, 1)


class TestRunnerValidation:
    """make_halo_runner's fuse/depth checks (1x1 mesh, in-process)."""

    def _mesh(self):
        return jax.make_mesh((1, 1), ("data", "model"))

    def test_fuse_must_divide_iterations(self):
        with pytest.raises(ValueError, match="not divisible"):
            make_halo_runner(self._mesh(), laplace_jacobi(2), H=8, W=8,
                             bc_value=0.0, iterations=5, fuse=2)

    def test_fuse_must_be_positive(self):
        with pytest.raises(ValueError, match="fuse"):
            make_halo_runner(self._mesh(), laplace_jacobi(2), H=8, W=8,
                             bc_value=0.0, iterations=4, fuse=0)

    def test_halo_depth_bounded_by_local_tile(self):
        with pytest.raises(ValueError, match="max fuse"):
            make_halo_runner(self._mesh(), laplace_jacobi(2), H=8, W=8,
                             bc_value=0.0, iterations=16, fuse=16)

    def test_radius2_halves_the_depth_budget(self):
        spec = star(2, [0.15, 0.05], center=0.2)
        with pytest.raises(ValueError, match="max fuse"):
            make_halo_runner(self._mesh(), spec, H=8, W=8, bc_value=0.0,
                             iterations=8, fuse=8)  # R = 16 > 8
        make_halo_runner(self._mesh(), spec, H=8, W=8, bc_value=0.0,
                         iterations=8, fuse=4)      # R = 8 fits


@pytest.mark.slow
class TestExchange1D:
    def test_radius2_extents_and_nonwrapping_zero_edges(
            self, run_with_devices):
        out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.parallel.halo import exchange_1d, shard_map_compat

        n, loc, r = 4, 4, 2
        mesh = jax.make_mesh((n,), ("x",))
        g = jnp.arange(1, n * loc + 1, dtype=jnp.float32)  # no zeros inside

        def f(xl):
            lo, hi = exchange_1d(xl, "x", n, 0, r)
            assert lo.shape == hi.shape == (r,)
            return jnp.concatenate([lo, hi])

        halos = np.asarray(shard_map_compat(
            f, mesh, (P("x"),), P("x"))(g)).reshape(n, 2 * r)
        gp = np.pad(np.asarray(g), r)  # zero-padded global line
        for i in range(n):
            np.testing.assert_array_equal(halos[i, :r],
                                          gp[i * loc: i * loc + r])
            np.testing.assert_array_equal(
                halos[i, r:], gp[(i + 1) * loc + r: (i + 1) * loc + 2 * r])
        # edge shards saw literal zeros, not wrapped values
        assert (halos[0, :r] == 0).all() and (halos[-1, r:] == 0).all()
        print("ex1d ok")
        """)
        assert "ex1d ok" in out

    def test_depth_beyond_local_extent_rejected(self, run_with_devices):
        out = run_with_devices("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.parallel.halo import exchange_1d, shard_map_compat

        n, loc = 4, 4
        mesh = jax.make_mesh((n,), ("x",))
        g = jnp.zeros((n * loc,), jnp.float32)
        try:
            shard_map_compat(
                lambda xl: exchange_1d(xl, "x", n, 0, loc + 1)[0],
                mesh, (P("x"),), P("x"))(g)
        except ValueError as e:
            assert "exceeds the local extent" in str(e), e
            print("depth-guard ok")
        """)
        assert "depth-guard ok" in out


@pytest.mark.slow
class TestExchange2D:
    def test_corner_transit_and_deep_halo_window(self, run_with_devices):
        # The two-phase exchange must deliver the exact window of the
        # zero-padded global grid — including the corner cells that only a
        # diagonal neighbour owns (they transit through the row phase) —
        # at radius 2 and at the deepest legal halo (r == local extent,
        # where one phase forwards a whole neighbouring tile).
        out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.parallel.halo import exchange_halo_2d, shard_map_compat

        nr, nc = 2, 4
        H, W = 8, 16
        hl, wl = H // nr, W // nc
        g = jnp.arange(1, H * W + 1, dtype=jnp.float32).reshape(H, W)
        mesh = jax.make_mesh((nr, nc), ("row", "col"))

        for r in (2, min(hl, wl)):
            gp = jnp.pad(g, r)

            def f(xl):
                aug = exchange_halo_2d(xl, "row", "col", nr, nc, r)
                ri = jax.lax.axis_index("row")
                ci = jax.lax.axis_index("col")
                want = jax.lax.dynamic_slice(
                    gp, (ri * hl, ci * wl), (hl + 2 * r, wl + 2 * r))
                return jnp.all(aug == want)[None, None]

            ok = shard_map_compat(f, mesh, (P("row", "col"),),
                                  P("row", "col"))(g)
            assert np.asarray(ok).all(), f"r={r}"
        print("corner ok")
        """)
        assert "corner ok" in out
