"""MoE dispatch-mode equivalence, capacity semantics, and vocab padding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import init_params
from repro.models.moe import moe_apply, moe_table

RNG = np.random.default_rng(17)


class TestDispatchModes:
    def _setup(self, D=32, E=8, F=64):
        params = init_params(moe_table(D, E, F), jax.random.PRNGKey(0),
                             jnp.float32)
        x = jnp.asarray(RNG.standard_normal((2, 64, D)), jnp.float32)
        return params, x

    def test_scatter_equals_einsum(self):
        params, x = self._setup()
        a, _ = moe_apply(params, x, top_k=2, group_size=64,
                         dispatch_mode="einsum")
        b, _ = moe_apply(params, x, top_k=2, group_size=64,
                         dispatch_mode="scatter")
        np.testing.assert_allclose(a, b, atol=1e-5)

    def test_scatter_grads_match(self):
        params, x = self._setup()
        def loss(mode):
            return lambda p: jnp.sum(
                moe_apply(p, x, top_k=2, group_size=64, dispatch_mode=mode)[0]
                ** 2)
        ga = jax.grad(loss("einsum"))(params)
        gb = jax.grad(loss("scatter"))(params)
        for u, v in zip(jax.tree.leaves(ga), jax.tree.leaves(gb)):
            np.testing.assert_allclose(u, v, atol=5e-3)

    def test_wave_count_invariance(self):
        params, x = self._setup()
        a, _ = moe_apply(params, x, top_k=2, group_size=16, n_waves=1)
        b, _ = moe_apply(params, x, top_k=2, group_size=16, n_waves=4)
        np.testing.assert_allclose(a, b, atol=1e-5)

    def test_full_capacity_routes_everything(self):
        # cf high enough -> no drops: output = sum_k gate_k * expert_k(x)
        params, x = self._setup(E=4)
        out, _ = moe_apply(params, x, top_k=4, capacity_factor=8.0,
                           group_size=64)
        # dense reference over all experts
        logits = jnp.einsum("bsd,de->bse", x, params["router"])
        probs = jax.nn.softmax(logits, -1)
        up = jnp.einsum("bsd,edf->bsef", x, params["up"])
        gate = jnp.einsum("bsd,edf->bsef", x, params["gate"])
        h = jax.nn.silu(gate) * up
        eo = jnp.einsum("bsef,efd->bsed", h, params["down"])
        ref = jnp.einsum("bsed,bse->bsd", eo, probs)
        np.testing.assert_allclose(out, ref, atol=1e-4)


class TestVocabPadding:
    def test_padded_vocab_values(self):
        from repro.configs import get_config
        assert get_config("mamba2-370m").padded_vocab == 50304
        assert get_config("whisper-tiny").padded_vocab == 51968
        # already divisible -> unchanged
        assert get_config("glm4-9b").padded_vocab == 151552
        assert get_config("qwen3-0.6b").padded_vocab == 151936

    def test_loss_invariant_to_padding(self):
        from repro.train.loss import chunked_xent
        B, S, D, V = 2, 8, 16, 50
        lm = jnp.asarray(RNG.standard_normal((V, D)), jnp.float32)
        h = jnp.asarray(RNG.standard_normal((B, S, D)), jnp.float32)
        y = jnp.asarray(RNG.integers(0, V, (B, S)))
        base = chunked_xent(lm, h, y)
        lm_pad = jnp.concatenate(
            [lm, jnp.asarray(RNG.standard_normal((14, D)), jnp.float32)])
        padded = chunked_xent(lm_pad, h, y, valid_vocab=V)
        assert float(base) == pytest.approx(float(padded), rel=1e-6)

    def test_decode_never_emits_pad_token(self):
        from repro.configs import get_config
        from repro.models.model_zoo import build
        cfg = get_config("mamba2-370m", smoke=True)
        # smoke vocab 512 is divisible; force a padded variant
        import dataclasses
        cfg = dataclasses.replace(cfg, vocab_size=500)
        assert cfg.padded_vocab == 512
        api = build(cfg)
        params = api.init(jax.random.PRNGKey(0), jnp.float32)
        batch = {"tokens": jnp.asarray(RNG.integers(0, 500, (2, 8)))}
        _, cache = api.prefill(params, batch, max_len=12)
        logits, _ = api.decode_step(params, batch["tokens"][:, -1], cache, 8)
        assert logits.shape[-1] == 512
        assert int(jnp.argmax(logits, -1).max()) < 500
