"""Substrate tests: optimizer, data pipeline determinism, checkpointing
(incl. elastic restore), fault-tolerant runtime restart-equivalence."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import Checkpointer
from repro.data.synthetic import DataConfig, token_batch
from repro.optim.adamw import AdamWConfig, apply_update, init_state, schedule


class TestAdamW:
    def test_quadratic_converges(self):
        target = jnp.asarray([1.0, -2.0, 3.0])
        state = init_state({"w": jnp.zeros(3)})
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=10,
                          total_steps=300)
        for _ in range(300):
            g = {"w": 2 * (state["params"]["w"] - target)}
            state, m = apply_update(state, g, cfg)
        np.testing.assert_allclose(state["params"]["w"], target, atol=1e-2)

    def test_grad_clip(self):
        state = init_state({"w": jnp.zeros(2)})
        cfg = AdamWConfig(lr=1e-3, grad_clip=1.0)
        _, m = apply_update(state, {"w": jnp.asarray([1e6, 0.0])}, cfg)
        assert float(m["grad_norm"]) > 1e5  # reported pre-clip

    def test_schedule_shape(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
        assert float(schedule(cfg, jnp.asarray(5))) == pytest.approx(0.5)
        assert float(schedule(cfg, jnp.asarray(100))) == pytest.approx(0.1, abs=1e-3)


class TestData:
    def test_deterministic_across_host_counts(self):
        cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=8)
        full = token_batch(cfg, step=3, n_hosts=1, host_id=0)
        parts = [token_batch(cfg, step=3, n_hosts=4, host_id=h)["tokens"]
                 for h in range(4)]
        np.testing.assert_array_equal(np.concatenate(parts), full["tokens"])

    def test_labels_are_shifted_tokens(self):
        cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=2)
        b = token_batch(cfg, 0)
        # same underlying stream: labels[t] == tokens[t+1]
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        tree = {"a": {"b": jnp.arange(5, dtype=jnp.float32)},
                "step": jnp.asarray(7)}
        ck.save(7, tree)
        step, back = ck.restore_latest()
        assert step == 7
        np.testing.assert_array_equal(back["a"]["b"], tree["a"]["b"])

    def test_keep_n(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            ck.save(s, {"x": jnp.zeros(1)})
        files = [f for f in os.listdir(tmp_path) if f.endswith(".npz")]
        assert len(files) == 2
        assert ck.latest_step() == 4

    def test_async_save(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        ck.save(1, {"x": jnp.ones(3)}, blocking=False)
        ck.wait()
        assert ck.latest_step() == 1

    def test_elastic_restore_on_different_mesh(self, tmp_path):
        # save unsharded, restore under an explicit (trivial) sharding -> works
        from jax.sharding import NamedSharding, PartitionSpec as P
        ck = Checkpointer(str(tmp_path))
        tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
        ck.save(2, tree)
        mesh = jax.make_mesh((1,), ("data",))
        sh = {"w": NamedSharding(mesh, P("data", None))}
        _, back = ck.restore_latest(sh)
        np.testing.assert_array_equal(back["w"], tree["w"])
        assert back["w"].sharding == sh["w"]


class TestFTRuntime:
    def _setup(self, tmp_path, fail_at=None):
        from repro.runtime.ft import FTConfig, run_training

        def train_step(state, batch):
            w = state["w"] - 0.1 * batch
            return {"w": w, "step": state["step"] + 1}, {"loss": jnp.sum(w * w)}

        def init():
            return {"w": jnp.ones(4), "step": jnp.asarray(0)}

        def batch_for(step):
            return jnp.full(4, float(step % 3))

        ft = FTConfig(checkpoint_dir=str(tmp_path), checkpoint_every=3,
                      async_save=False, fail_at_step=fail_at)
        return train_step, init, batch_for, ft, run_training

    def test_restart_equivalence(self, tmp_path):
        from repro.runtime.ft import InjectedFailure
        step, init, batch_for, ft, run = self._setup(tmp_path, fail_at=7)
        with pytest.raises(InjectedFailure):
            run(step, init, batch_for, 10, ft)
        ft2 = self._setup(tmp_path)[3]
        state, stats = run(step, init, batch_for, 10, ft2)

        # uninterrupted reference
        ref_state, _ = run(step, init, batch_for, 10,
                           self._setup(str(tmp_path) + "_ref")[3])
        np.testing.assert_allclose(state["w"], ref_state["w"], rtol=1e-6)

    def test_straggler_flagging(self, tmp_path):
        import time
        from repro.runtime.ft import FTConfig, run_training

        calls = {"n": 0}

        def train_step(state, batch):
            calls["n"] += 1
            if calls["n"] == 8:
                time.sleep(0.25)
            return state, {"loss": jnp.zeros(())}

        ft = FTConfig(checkpoint_dir=str(tmp_path), checkpoint_every=100,
                      async_save=False, straggler_factor=3.0)
        _, stats = run_training(train_step, lambda: {"w": jnp.zeros(1)},
                                lambda s: jnp.zeros(1), 10, ft)
        assert any(s.is_straggler for s in stats)
