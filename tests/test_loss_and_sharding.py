"""Chunked vocab-sharded loss vs direct xent; sharding rule unit tests;
hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip; the rest of the module still runs
    from _hypothesis_stub import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import Sharder
from repro.train.loss import chunked_xent

RNG = np.random.default_rng(11)


class TestChunkedXent:
    @pytest.mark.parametrize("n_chunks", [1, 2, 8])
    def test_matches_direct(self, n_chunks):
        B, S, D, V = 2, 16, 8, 50
        lm = jnp.asarray(RNG.standard_normal((V, D)), jnp.float32)
        h = jnp.asarray(RNG.standard_normal((B, S, D)), jnp.float32)
        y = jnp.asarray(RNG.integers(0, V, (B, S)))
        out = chunked_xent(lm, h, y, n_chunks=n_chunks)
        logits = jnp.einsum("bsd,vd->bsv", h, lm)
        direct = -jnp.mean(jnp.take_along_axis(
            jax.nn.log_softmax(logits, -1), y[..., None], -1))
        assert float(out) == pytest.approx(float(direct), rel=1e-5)

    def test_grads_match_direct(self):
        B, S, D, V = 2, 8, 8, 30
        lm = jnp.asarray(RNG.standard_normal((V, D)), jnp.float32)
        h = jnp.asarray(RNG.standard_normal((B, S, D)), jnp.float32)
        y = jnp.asarray(RNG.integers(0, V, (B, S)))
        g1 = jax.grad(lambda l: chunked_xent(l, h, y, n_chunks=4))(lm)
        def direct(l):
            logits = jnp.einsum("bsd,vd->bsv", h, l)
            return -jnp.mean(jnp.take_along_axis(
                jax.nn.log_softmax(logits, -1), y[..., None], -1))
        g2 = jax.grad(direct)(lm)
        np.testing.assert_allclose(g1, g2, atol=1e-5)

    @settings(max_examples=15, deadline=None)
    @given(b=st.integers(1, 4), s=st.integers(2, 24), v=st.integers(5, 80))
    def test_property_loss_bounded(self, b, s, v):
        # nll of any distribution over v classes lies in [0, ~log v + margin]
        lm = jnp.asarray(np.random.default_rng(v).standard_normal((v, 8)) * 0.1,
                         jnp.float32)
        h = jnp.asarray(np.random.default_rng(s).standard_normal((b, s, 8)),
                        jnp.float32)
        y = jnp.asarray(np.random.default_rng(b).integers(0, v, (b, s)))
        out = float(chunked_xent(lm, h, y))
        assert 0.0 <= out <= np.log(v) + 5.0


class _FakeMesh:
    """Duck-typed mesh: Sharder.spec only needs shape + axis_names."""

    def __init__(self, **shape):
        self.shape = shape
        self.axis_names = tuple(shape)


class TestSharderRules:
    MESH = _FakeMesh(data=16, model=16)

    def test_heads_shard_when_divisible(self):
        sh = Sharder(mesh=self.MESH, profile="tp")
        assert sh.spec(("embed", "heads", "head_dim"), (64, 48, 128)) == \
            P(None, "model", None)

    def test_heads_replicate_when_not_divisible(self):
        sh = Sharder(mesh=self.MESH, profile="tp")
        # whisper-tiny: 6 heads on a 16-wide axis -> replicated
        assert sh.spec(("embed", "heads", "head_dim"), (384, 6, 64)) == \
            P(None, None, None)

    def test_axis_used_once(self):
        sh = Sharder(mesh=self.MESH, profile="tp")
        spec = sh.spec(("vocab", "dff"), (1600, 1600))
        # both want "model"; second falls back to None
        assert spec == P("model", None)

    def test_batch_composite_multipod(self):
        sh = Sharder(mesh=_FakeMesh(pod=2, data=16, model=16), profile="tp")
        assert sh.spec(("batch", "seq"), (256, 4096)) == P(("pod", "data"), None)
        # batch=1 (long_500k): not divisible -> replicated
        assert sh.spec(("batch", "seq"), (1, 4096)) == P(None, None)

    def test_sp_profile_seq_shards(self):
        sh = Sharder(mesh=self.MESH, profile="sp")
        assert sh.spec(("batch", "seq", "embed"), (256, 4096, 5120)) == \
            P("data", "model", None)
        # weights ZeRO over data in sp
        assert sh.spec(("embed", "dff"), (5120, 17920)) == P("data", None)

    def test_opt_spec_adds_data_axis(self):
        sh = Sharder(mesh=self.MESH, profile="tp")
        # param: dff sharded on model; opt state also shards embed on data
        assert sh.opt_spec(("embed", "dff"), (64, 128)) == P("data", "model")

    def test_state_over_data_decode(self):
        sh = Sharder(mesh=self.MESH, profile="tp", state_over_data=True)
        spec = sh.spec(("batch", "ssm_heads", "ssm_headdim", "ssm_state"),
                       (1, 32, 64, 128))
        assert spec == P(None, "model", "data", None)


class TestHaloPerms:
    def test_shift_perm_non_wrapping(self):
        from repro.parallel.halo import _shift_perm
        assert _shift_perm(4, +1) == [(0, 1), (1, 2), (2, 3)]
        assert _shift_perm(4, -1) == [(1, 0), (2, 1), (3, 2)]

    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(2, 8))
    def test_perms_are_bijective_partial(self, n):
        from repro.parallel.halo import _shift_perm
        for d in (+1, -1):
            perm = _shift_perm(n, d)
            srcs = [a for a, _ in perm]
            dsts = [b for _, b in perm]
            assert len(set(srcs)) == len(srcs)
            assert len(set(dsts)) == len(dsts)


class TestHLOCostAnalyzer:
    def test_scan_trip_count(self):
        from repro.launch.hlo_cost import analyze

        def f(x, w):
            def body(x, wi):
                return x @ wi, None
            x, _ = jax.lax.scan(body, x, w)
            return x
        hlo = jax.jit(f).lower(
            jax.ShapeDtypeStruct((64, 64), jnp.float32),
            jax.ShapeDtypeStruct((12, 64, 64), jnp.float32)).compile().as_text()
        r = analyze(hlo)
        assert r["flops"] == pytest.approx(2 * 64**3 * 12, rel=0.01)

    def test_nested_scan_with_remat(self):
        from repro.launch.hlo_cost import analyze

        def g(x, w):
            w2 = w.reshape(4, 2, 32, 32)
            def outer(x, gw):
                def inner(x, wi):
                    return x @ wi, None
                x, _ = jax.lax.scan(inner, x, gw)
                return x, None
            x, _ = jax.lax.scan(jax.checkpoint(outer), x, w2)
            return jnp.sum(x)
        hlo = jax.jit(jax.grad(g, argnums=1)).lower(
            jax.ShapeDtypeStruct((16, 32), jnp.float32),
            jax.ShapeDtypeStruct((8, 32, 32), jnp.float32)).compile().as_text()
        r = analyze(hlo)
        # fwd + remat-fwd + 2x bwd = 4x fwd flops
        assert r["flops"] == pytest.approx(4 * 2 * 16 * 32 * 32 * 8, rel=0.05)
