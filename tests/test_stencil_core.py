"""Core stencil DSL: every encoding must match the reference oracle, and the
FLOP accounting must match the paper's §4 numbers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BoundaryMode,
    DirichletBC,
    StencilSpec,
    box,
    build_dense_matrix,
    conv_jacobi_2d,
    conv_jacobi_3d_channels,
    conv_jacobi_3d_native,
    dense_jacobi_with_bc,
    encoding_flops_per_point,
    jacobi_reference,
    laplace_jacobi,
    star,
)

RNG = np.random.default_rng(42)


def _ref(x0, spec, bc, iters):
    return jnp.stack([jacobi_reference(x0[i], spec, bc, iters)
                      for i in range(x0.shape[0])])


class TestPaperFlopAccounting:
    def test_useful_flops_2d(self):
        # paper §4: "7 useful calculations ... four multiplications and three additions"
        assert laplace_jacobi(2).useful_flops_per_point == 7

    def test_conv_flops_2d(self):
        # paper §4: "convolution layer by contrast undertakes 17 operations"
        assert laplace_jacobi(2).delivered_flops_per_point_conv() == 17

    def test_dense_flops_n4096(self):
        # paper §4: "with X=Y=64 and therefore N=4096, there are 8191 operations"
        assert laplace_jacobi(2).delivered_flops_per_point_dense(4096) == 8191

    def test_conv_total_ops_64x64(self):
        # paper §4: "69632 total operations for the 2D case where X=Y=64"
        spec = laplace_jacobi(2)
        assert spec.delivered_flops_per_point_conv() * 64 * 64 == 69632

    def test_dense_total_ops_64x64(self):
        # paper §4: "33550336 total calculations for the entire input tensor"
        spec = laplace_jacobi(2)
        assert spec.delivered_flops_per_point_dense(4096) * 4096 == 33550336

    def test_mask_trick_overhead(self):
        spec = laplace_jacobi(2)
        assert (encoding_flops_per_point(spec, "conv", mask_trick=True)
                - encoding_flops_per_point(spec, "conv", mask_trick=False)) == 2


class TestSpec:
    def test_laplace_2d_kernel_matches_fig2(self):
        ker = laplace_jacobi(2).to_kernel()
        expect = np.array([[0, .25, 0], [.25, 0, .25], [0, .25, 0]], np.float32)
        np.testing.assert_array_equal(ker, expect)

    def test_radius_and_footprint(self):
        assert laplace_jacobi(3).radius == 1
        assert laplace_jacobi(3).footprint == (3, 3, 3)
        assert star(2, [0.1, 0.2]).radius == 2

    def test_spec_is_hashable(self):
        hash(laplace_jacobi(2))
        assert laplace_jacobi(2) == laplace_jacobi(2)


class TestEncodings2D:
    @pytest.mark.parametrize("shape", [(1, 8, 8), (2, 13, 9), (1, 24, 17)])
    @pytest.mark.parametrize("bc_val", [0.0, 1.0, -2.5])
    def test_dense_matches_reference(self, shape, bc_val):
        spec = laplace_jacobi(2)
        bc = DirichletBC(bc_val)
        x0 = jnp.asarray(RNG.standard_normal(shape), jnp.float32)
        ref = _ref(x0, spec, bc, 5)
        out = dense_jacobi_with_bc(x0, spec, bc, 5)
        np.testing.assert_allclose(out, ref, atol=1e-5)

    @pytest.mark.parametrize("mode", [BoundaryMode.MASK, BoundaryMode.PAD])
    def test_conv_matches_reference(self, mode):
        spec = laplace_jacobi(2)
        bc = DirichletBC(1.5)
        x0 = jnp.asarray(RNG.standard_normal((2, 16, 12)), jnp.float32)
        ref = _ref(x0, spec, bc, 6)
        out = conv_jacobi_2d(x0, spec, bc, 6, mode)
        np.testing.assert_allclose(out, ref, atol=1e-5)

    def test_dense_matrix_has_identity_boundary_rows(self):
        # paper Fig 1: boundary cells keep their value via 1 on the diagonal
        m = build_dense_matrix((3, 3), laplace_jacobi(2))
        for i in range(9):
            if i != 4:
                assert m[i, i] == 1.0
        assert m[4, 4] == 0.0
        assert m[1, 4] == 0.25  # neighbour contribution into the centre

    def test_box_stencil(self):
        spec = box(2)
        bc = DirichletBC(0.5)
        x0 = jnp.asarray(RNG.standard_normal((1, 10, 10)), jnp.float32)
        ref = _ref(x0, spec, bc, 3)
        out = conv_jacobi_2d(x0, spec, bc, 3, BoundaryMode.MASK)
        np.testing.assert_allclose(out, ref, atol=1e-5)


class TestEncodings3D:
    def test_channels_trick_matches_reference(self):
        # paper Figures 3-4: 3D via Conv2D channels
        spec = laplace_jacobi(3)
        bc = DirichletBC(1.0)
        x0 = jnp.asarray(RNG.standard_normal((1, 10, 12, 8)), jnp.float32)
        ref = _ref(x0, spec, bc, 4)
        out = conv_jacobi_3d_channels(x0, spec, bc, 4)
        np.testing.assert_allclose(out, ref, atol=1e-5)

    def test_native_conv3d_matches_channels_trick(self):
        spec = laplace_jacobi(3)
        bc = DirichletBC(2.0)
        x0 = jnp.asarray(RNG.standard_normal((1, 6, 9, 7)), jnp.float32)
        a = conv_jacobi_3d_channels(x0, spec, bc, 3)
        b = conv_jacobi_3d_native(x0, spec, bc, 3)
        np.testing.assert_allclose(a, b, atol=1e-5)

    def test_dense_3d(self):
        spec = laplace_jacobi(3)
        bc = DirichletBC(0.0)
        x0 = jnp.asarray(RNG.standard_normal((1, 5, 6, 4)), jnp.float32)
        ref = _ref(x0, spec, bc, 2)
        out = dense_jacobi_with_bc(x0, spec, bc, 2)
        np.testing.assert_allclose(out, ref, atol=1e-5)


class TestConvergence:
    def test_jacobi_converges_to_bc_value(self):
        # Laplace with constant Dirichlet BC converges to the constant
        spec = laplace_jacobi(2)
        bc = DirichletBC(3.0)
        x0 = jnp.asarray(RNG.standard_normal((1, 8, 8)), jnp.float32)
        out = conv_jacobi_2d(x0, spec, bc, 500)
        np.testing.assert_allclose(out, 3.0, atol=1e-3)
