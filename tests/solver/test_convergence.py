"""Solver-tier convergence tests: analytic solutions, residual behaviour,
chunking/fuse invariance, and batched-vs-loop equivalence.

Two analytic problems pin the solver down end to end:

  * Laplace on the unit square with ``u = sin(pi x)`` on the top wall and 0
    elsewhere — known series solution ``u = sinh(pi y) sin(pi x)/sinh(pi)``;
    Jacobi must converge to it within the O(h^2) discretization error.
  * Explicit heat stepping ``x <- x + c*Lap(x)`` with zero walls, started on
    the fundamental eigenmode — the field decays *exactly* by the known
    eigenvalue per step, so both the fixed-iteration trajectory and the
    iterations-to-convergence count are predictable in closed form.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BoundaryMode,
    DirichletBC,
    Solver,
    StencilSpec,
    laplace_jacobi,
    solve,
)

RNG = np.random.default_rng(20260802)


def heat_spec(c: float) -> StencilSpec:
    """Explicit 2D heat-equation step: out = x + c * (5-point Laplacian)."""
    taps = {(0, 0): 1.0 - 4 * c, (1, 0): c, (-1, 0): c, (0, 1): c, (0, -1): c}
    return StencilSpec(taps=taps, name="heat2d")


def heat_mode(n: int) -> np.ndarray:
    """Fundamental eigenmode of the zero-wall heat step on an n×n grid."""
    s = np.sin(np.pi * np.arange(n) / (n - 1))
    return np.outer(s, s).astype(np.float32)


class TestAnalyticLaplace:
    """Converge to the series solution of Laplace on a rectangle."""

    N = 24

    def _problem(self):
        n = self.N
        xs = np.linspace(0.0, 1.0, n)
        bc_grid = np.zeros((n, n), np.float32)
        bc_grid[-1, :] = np.sin(np.pi * xs)          # hot top wall
        ys = xs[:, None]
        analytic = (np.sinh(np.pi * ys) / np.sinh(np.pi)
                    * np.sin(np.pi * xs)[None, :]).astype(np.float32)
        return DirichletBC(jnp.asarray(bc_grid)), analytic

    @pytest.mark.parametrize("backend,mode", [
        ("reference", BoundaryMode.MASK),
        ("conv", BoundaryMode.MASK),
        ("dense", BoundaryMode.MATRIX),
    ])
    def test_converges_to_series_solution(self, backend, mode):
        bc, analytic = self._problem()
        res = solve(laplace_jacobi(2), jnp.zeros((self.N, self.N), jnp.float32),
                    backend=backend, bc=bc, mode=mode, rtol=0.0, atol=2e-5,
                    check_every=50, max_iters=6000)
        assert res.converged, res.residual
        assert res.backend == backend
        # iteration error (~atol/(1-rho)) + O(h^2) discretization error
        err = float(np.abs(np.asarray(res.x) - analytic).max())
        assert err < 0.02, err

    def test_backends_agree_at_convergence(self):
        bc, _ = self._problem()
        fields = [
            np.asarray(solve(laplace_jacobi(2),
                             jnp.zeros((self.N, self.N), jnp.float32),
                             backend=b, bc=bc, mode=m, rtol=0.0, atol=2e-5,
                             check_every=50, max_iters=6000).x)
            for b, m in (("reference", BoundaryMode.MASK),
                         ("conv", BoundaryMode.MASK),
                         ("dense", BoundaryMode.MATRIX))
        ]
        for f in fields[1:]:
            np.testing.assert_allclose(f, fields[0], atol=1e-3)


class TestAnalyticHeatDecay:
    """The eigenmode decays by exactly mu per step; both the trajectory and
    the iterations-to-convergence count follow in closed form."""

    N = 16
    C = 0.15

    @pytest.mark.parametrize(
        "backend", ["reference", "conv", "pallas", "pallas_fused"])
    def test_fixed_iteration_decay_rate(self, backend):
        v0 = heat_mode(self.N)
        mu = self._mu()
        k = 120
        res = solve(heat_spec(self.C), jnp.asarray(v0), backend=backend,
                    bc=0.0, rtol=None, atol=None, max_iters=k)
        assert res.iterations == k and not res.converged
        np.testing.assert_allclose(np.asarray(res.x), mu**k * v0, atol=1e-3)

    @pytest.mark.parametrize("backend", ["reference", "conv", "pallas"])
    def test_iterations_to_convergence_match_theory(self, backend):
        v0 = heat_mode(self.N)
        mu = self._mu()
        atol, check = 1e-5, 50
        res = solve(heat_spec(self.C), jnp.asarray(v0), backend=backend,
                    bc=0.0, rtol=0.0, atol=atol, check_every=check,
                    max_iters=2000)
        assert res.converged
        # residual after chunk m: (1 - mu^C) * mu^{(m-1)C} * ||v0||_2
        norm0 = float(np.linalg.norm(v0))
        m = 1
        while (1 - mu**check) * mu**((m - 1) * check) * norm0 > atol:
            m += 1
        assert abs(res.iterations - m * check) <= check, \
            (res.iterations, m * check)
        assert float(np.abs(np.asarray(res.x)).max()) < 1e-2

    def _mu(self) -> float:
        # eigenvalue of the heat step on the fundamental mode:
        # 1 - 4c + 4c*cos(pi/(N-1))
        return 1.0 - 4 * self.C * (1.0 - np.cos(np.pi / (self.N - 1)))


class TestResidualBehaviour:
    def test_residual_history_is_monotone(self):
        x0 = jnp.asarray(RNG.standard_normal((16, 16)), jnp.float32)
        res = solve(laplace_jacobi(2), x0, backend="conv", bc=1.0, rtol=1e-6,
                    check_every=5, max_iters=3000)
        assert res.converged
        h = res.residual_history
        assert len(h) >= 3
        assert not np.isnan(h).any()
        assert np.all(h[1:] <= h[:-1] * (1 + 1e-6) + 1e-7), h

    def test_residual_matches_history_tail(self):
        res = solve(laplace_jacobi(2), jnp.zeros((12, 12), jnp.float32),
                    bc=1.0, rtol=1e-6, check_every=10, max_iters=2000)
        assert res.converged
        assert res.residual == pytest.approx(res.residual_history[-1])

    def test_max_iters_safety(self):
        res = solve(laplace_jacobi(2), jnp.zeros((16, 16), jnp.float32),
                    bc=1.0, rtol=1e-12, check_every=10, max_iters=40)
        assert not res.converged
        assert res.iterations == 40
        assert len(res.residual_history) == 4

    def test_unsatisfiable_criterion_rejected(self):
        # rtol=None alone is NOT fixed-iteration mode (atol still defaults
        # to 0.0 -> err <= 0 can never hold); fail loudly instead of
        # silently looping to max_iters
        with pytest.raises(ValueError, match="unsatisfiable"):
            solve(laplace_jacobi(2), jnp.zeros((8, 8), jnp.float32),
                  bc=1.0, rtol=None)
        with pytest.raises(ValueError, match="unsatisfiable"):
            solve(laplace_jacobi(2), jnp.zeros((8, 8), jnp.float32),
                  bc=1.0, rtol=0.0, atol=0.0)

    def test_linf_norm_criterion(self):
        res = solve(laplace_jacobi(2), jnp.zeros((16, 16), jnp.float32),
                    bc=1.0, rtol=0.0, atol=1e-6, norm="linf",
                    check_every=20, max_iters=5000)
        assert res.converged
        assert float(np.abs(np.asarray(res.x) - 1.0).max()) < 1e-3


class TestChunkingInvariance:
    """The converged answer must not depend on how the time loop is chunked
    (check_every) or temporally fused (fuse depth)."""

    def test_check_every_invariance(self):
        x0 = jnp.asarray(RNG.standard_normal((16, 16)), jnp.float32)
        fields = [
            np.asarray(solve(laplace_jacobi(2), x0, backend="conv", bc=1.0,
                             rtol=1e-6, check_every=c, max_iters=4000).x)
            for c in (10, 20, 40)
        ]
        for f in fields:
            np.testing.assert_allclose(f, np.ones_like(f), atol=2e-3)
        for f in fields[1:]:
            np.testing.assert_allclose(f, fields[0], atol=5e-3)

    def test_fuse_depth_invariance_fixed(self):
        x0 = jnp.asarray(RNG.standard_normal((16, 16)), jnp.float32)
        outs = [
            np.asarray(solve(laplace_jacobi(2), x0, backend="pallas", bc=1.0,
                             rtol=None, atol=None, max_iters=16, fuse=f).x)
            for f in (1, 4, 8)
        ]
        for o in outs[1:]:
            np.testing.assert_allclose(o, outs[0], atol=1e-5)

    def test_fuse_depth_invariance_converged(self):
        x0 = jnp.asarray(RNG.standard_normal((16, 16)), jnp.float32)
        a = solve(laplace_jacobi(2), x0, backend="pallas_fused", bc=1.0,
                  rtol=1e-6, check_every=16, max_iters=2000, fuse=1)
        b = solve(laplace_jacobi(2), x0, backend="pallas_fused", bc=1.0,
                  rtol=1e-6, check_every=16, max_iters=2000, fuse=8)
        assert a.iterations == b.iterations
        assert b.fuse == 8
        np.testing.assert_allclose(np.asarray(a.x), np.asarray(b.x), atol=1e-5)


class TestBatchedMode:
    def test_batched_matches_instance_by_instance(self):
        x0 = jnp.stack([
            jnp.zeros((16, 16)),
            0.5 * jnp.ones((16, 16)),
            jnp.asarray(RNG.standard_normal((16, 16))),
        ]).astype(jnp.float32)
        batched = solve(laplace_jacobi(2), x0, backend="conv", bc=1.0,
                        rtol=1e-6, check_every=10, max_iters=4000)
        assert batched.converged.all()
        singles = [solve(laplace_jacobi(2), x0[i], backend="conv", bc=1.0,
                         rtol=1e-6, check_every=10, max_iters=4000)
                   for i in range(3)]
        np.testing.assert_array_equal(
            batched.iterations, [s.iterations for s in singles])
        for i, s in enumerate(singles):
            np.testing.assert_allclose(np.asarray(batched.x[i]),
                                       np.asarray(s.x), atol=1e-6)
            assert batched.residual[i] == pytest.approx(s.residual, rel=1e-4)

    def test_frozen_instances_stop_recording_history(self):
        # instance 0 starts at the fixed point -> converges in one chunk
        x0 = jnp.stack([jnp.ones((16, 16)),
                        jnp.zeros((16, 16))]).astype(jnp.float32)
        res = solve(laplace_jacobi(2), x0, backend="conv", bc=1.0,
                    rtol=1e-6, check_every=10, max_iters=4000)
        assert res.converged.all()
        assert res.iterations[0] < res.iterations[1]
        h = res.residual_history
        # instance 0's rows go NaN once frozen; instance 1's stay recorded
        assert np.isnan(h[1:, 0]).all()
        assert not np.isnan(h[:, 1]).any()

    def test_solver_reuse_across_batch_shapes(self):
        s = Solver(laplace_jacobi(2), (12, 12), backend="conv", bc=1.0,
                   rtol=1e-6, check_every=10, max_iters=2000)
        r1 = s.solve(jnp.zeros((12, 12), jnp.float32))
        r2 = s.solve(jnp.zeros((2, 12, 12), jnp.float32))
        assert r1.converged and r2.converged.all()
        assert r1.iterations == r2.iterations[0]
