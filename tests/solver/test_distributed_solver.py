"""Multi-device solver tests: distributed ``solve()`` on a forced 8-device
CPU mesh must match the single-device solve per step and at convergence.

Each case runs in a subprocess (the ``run_with_devices`` fixture from
tests/conftest.py) so the main test process keeps its single-device view.
"""
import pytest

pytestmark = pytest.mark.slow


class TestDistributedSolve:
    def test_matches_single_device_per_step_and_at_convergence(
            self, run_with_devices):
        out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import laplace_jacobi, solve

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        spec = laplace_jacobi(2)
        rng = np.random.default_rng(0)
        x0 = jnp.asarray(rng.standard_normal((2, 16, 16)), jnp.float32)

        # per-step: k fixed iterations through the sharded halo-exchange
        # chunk equal the single-device oracle's k steps
        for k in (1, 3, 10):
            d = solve(spec, x0, backend="halo", mesh=mesh, bc=1.0,
                      rtol=None, atol=None, max_iters=k)
            s = solve(spec, x0, backend="reference", bc=1.0,
                      rtol=None, atol=None, max_iters=k)
            err = float(jnp.abs(d.x - s.x).max())
            assert err < 1e-5, (k, err)

        # at convergence: same iteration counts, same field
        d = solve(spec, x0, backend="halo", mesh=mesh, bc=1.0,
                  rtol=1e-6, check_every=10, max_iters=2000)
        s = solve(spec, x0, backend="reference", bc=1.0,
                  rtol=1e-6, check_every=10, max_iters=2000)
        assert d.converged.all() and s.converged.all()
        assert np.array_equal(d.iterations, s.iterations), \
            (d.iterations, s.iterations)
        err = float(jnp.abs(d.x - s.x).max())
        assert err < 1e-5, err
        assert d.backend == "halo"
        print("dist-solve ok", err)
        """)
        assert "dist-solve ok" in out

    def test_nine_point_corners_ride_the_exchange(self, run_with_devices):
        out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import box, solve

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        spec = box(2)   # 9-point: corner halos must survive the two phases
        rng = np.random.default_rng(1)
        x0 = jnp.asarray(rng.standard_normal((1, 8, 16)), jnp.float32)
        d = solve(spec, x0, backend="halo", mesh=mesh, bc=0.5,
                  rtol=None, atol=None, max_iters=3)
        s = solve(spec, x0, backend="reference", bc=0.5,
                  rtol=None, atol=None, max_iters=3)
        err = float(jnp.abs(d.x - s.x).max())
        assert err < 1e-5, err
        print("box-solve ok", err)
        """)
        assert "box-solve ok" in out

    def test_fused_matches_single_device_both_parities(self,
                                                       run_with_devices):
        # Deep-halo fusion: fuse=k chunks must equal the single-device solve
        # per chunk and at convergence, on both local-tile parities (16x16
        # over (2,2) gives even 8x8 tiles; 18x18 gives odd 9x9 tiles, so
        # every trapezoid margin arithmetic path runs).
        out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import laplace_jacobi, solve

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        spec = laplace_jacobi(2)
        rng = np.random.default_rng(2)
        for n in (16, 18):
            x0 = jnp.asarray(rng.standard_normal((2, n, n)), jnp.float32)
            # per chunk: one fixed 8-iteration chunk at each fuse depth
            s = solve(spec, x0, backend="reference", bc=1.0,
                      rtol=None, atol=None, max_iters=8)
            for fuse in (2, 4):
                d = solve(spec, x0, backend="halo", mesh=mesh, bc=1.0,
                          fuse=fuse, rtol=None, atol=None, max_iters=8)
                err = float(jnp.abs(d.x - s.x).max())
                assert d.fuse == fuse and err < 1e-5, (n, fuse, err)
            # at convergence: fuse divides check_every, counts must agree
            d = solve(spec, x0, backend="halo", mesh=mesh, bc=1.0, fuse=4,
                      rtol=1e-6, check_every=16, max_iters=2000)
            s = solve(spec, x0, backend="reference", bc=1.0,
                      rtol=1e-6, check_every=16, max_iters=2000)
            assert d.converged.all() and s.converged.all(), n
            assert np.array_equal(d.iterations, s.iterations), n
            err = float(jnp.abs(d.x - s.x).max())
            assert err < 1e-5, (n, err)
        print("fused-dist ok")
        """)
        assert "fused-dist ok" in out

    def test_fused_deep_halo_radius2_and_corners(self, run_with_devices):
        # radius-2 star at fuse=2 exchanges a 4-deep halo; box corners must
        # survive the deep two-phase exchange through every fused substep.
        out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import box, solve, star

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rng = np.random.default_rng(3)
        x0 = jnp.asarray(rng.standard_normal((1, 16, 32)), jnp.float32)
        for spec in (star(2, [0.15, 0.05], center=0.2), box(2)):
            d = solve(spec, x0, backend="halo", mesh=mesh, bc=0.5, fuse=2,
                      rtol=None, atol=None, max_iters=6)
            s = solve(spec, x0, backend="reference", bc=0.5,
                      rtol=None, atol=None, max_iters=6)
            err = float(jnp.abs(d.x - s.x).max())
            assert err < 1e-5, (spec.name, err)
        print("deep-halo ok")
        """)
        assert "deep-halo ok" in out

    def test_variable_coefficients_shard_with_the_grid(self,
                                                       run_with_devices):
        # Per-cell weight fields shard P(None, row, col) and are exchanged
        # once per chunk; the fused distributed solve must match the oracle.
        out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import heterogeneous_jacobi, solve

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rng = np.random.default_rng(4)
        kappa = 1.0 + 9.0 * rng.random((16, 16)).astype(np.float32)
        spec = heterogeneous_jacobi(kappa)
        x0 = jnp.asarray(rng.standard_normal((2, 16, 16)), jnp.float32)
        for fuse in (1, 3):
            d = solve(spec, x0, backend="halo", mesh=mesh, bc=1.0, fuse=fuse,
                      rtol=None, atol=None, max_iters=6)
            s = solve(spec, x0, backend="reference", bc=1.0,
                      rtol=None, atol=None, max_iters=6)
            err = float(jnp.abs(d.x - s.x).max())
            assert err < 1e-5, (fuse, err)
        print("varcoef-dist ok")
        """)
        assert "varcoef-dist ok" in out

    def test_solver_auto_selects_legal_halo_fuse(self, run_with_devices):
        # select_fuse must hand the solver a depth that divides check_every
        # and fits the local tile — and the result still matches.
        out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import laplace_jacobi, solve

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        spec = laplace_jacobi(2)
        rng = np.random.default_rng(5)
        x0 = jnp.asarray(rng.standard_normal((16, 16)), jnp.float32)
        d = solve(spec, x0, backend="halo", mesh=mesh, bc=1.0,
                  rtol=1e-6, check_every=12, max_iters=1200, tuned=None)
        assert d.fuse >= 1 and 12 % d.fuse == 0, d.fuse
        assert d.fuse * spec.radius <= min(16 // 2, 16 // 4), d.fuse
        s = solve(spec, x0, backend="reference", bc=1.0,
                  rtol=1e-6, check_every=12, max_iters=1200)
        err = float(jnp.abs(d.x - s.x).max())
        assert err < 1e-5, err
        print("auto-fuse ok", d.fuse)
        """)
        assert "auto-fuse ok" in out

    def test_batched_distributed_convergence(self, run_with_devices):
        out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import laplace_jacobi, solve

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        spec = laplace_jacobi(2)
        x0 = jnp.stack([jnp.zeros((16, 16)),
                        0.5 * jnp.ones((16, 16))]).astype(jnp.float32)
        d = solve(spec, x0, backend="halo", mesh=mesh, bc=1.0,
                  rtol=1e-6, check_every=10, max_iters=2000)
        s = solve(spec, x0, backend="reference", bc=1.0,
                  rtol=1e-6, check_every=10, max_iters=2000)
        assert d.converged.all()
        assert np.array_equal(d.iterations, s.iterations)
        err = float(jnp.abs(d.x - s.x).max())
        assert err < 1e-5, err
        print("batched-dist ok", list(map(int, d.iterations)))
        """)
        assert "batched-dist ok" in out
