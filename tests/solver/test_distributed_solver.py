"""Multi-device solver tests: distributed ``solve()`` on a forced 8-device
CPU mesh must match the single-device solve per step and at convergence.

Each case runs in a subprocess (the ``run_with_devices`` fixture from
tests/conftest.py) so the main test process keeps its single-device view.
"""
import pytest

pytestmark = pytest.mark.slow


class TestDistributedSolve:
    def test_matches_single_device_per_step_and_at_convergence(
            self, run_with_devices):
        out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import laplace_jacobi, solve

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        spec = laplace_jacobi(2)
        rng = np.random.default_rng(0)
        x0 = jnp.asarray(rng.standard_normal((2, 16, 16)), jnp.float32)

        # per-step: k fixed iterations through the sharded halo-exchange
        # chunk equal the single-device oracle's k steps
        for k in (1, 3, 10):
            d = solve(spec, x0, backend="halo", mesh=mesh, bc=1.0,
                      rtol=None, atol=None, max_iters=k)
            s = solve(spec, x0, backend="reference", bc=1.0,
                      rtol=None, atol=None, max_iters=k)
            err = float(jnp.abs(d.x - s.x).max())
            assert err < 1e-5, (k, err)

        # at convergence: same iteration counts, same field
        d = solve(spec, x0, backend="halo", mesh=mesh, bc=1.0,
                  rtol=1e-6, check_every=10, max_iters=2000)
        s = solve(spec, x0, backend="reference", bc=1.0,
                  rtol=1e-6, check_every=10, max_iters=2000)
        assert d.converged.all() and s.converged.all()
        assert np.array_equal(d.iterations, s.iterations), \
            (d.iterations, s.iterations)
        err = float(jnp.abs(d.x - s.x).max())
        assert err < 1e-5, err
        assert d.backend == "halo"
        print("dist-solve ok", err)
        """)
        assert "dist-solve ok" in out

    def test_nine_point_corners_ride_the_exchange(self, run_with_devices):
        out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import box, solve

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        spec = box(2)   # 9-point: corner halos must survive the two phases
        rng = np.random.default_rng(1)
        x0 = jnp.asarray(rng.standard_normal((1, 8, 16)), jnp.float32)
        d = solve(spec, x0, backend="halo", mesh=mesh, bc=0.5,
                  rtol=None, atol=None, max_iters=3)
        s = solve(spec, x0, backend="reference", bc=0.5,
                  rtol=None, atol=None, max_iters=3)
        err = float(jnp.abs(d.x - s.x).max())
        assert err < 1e-5, err
        print("box-solve ok", err)
        """)
        assert "box-solve ok" in out

    def test_batched_distributed_convergence(self, run_with_devices):
        out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import laplace_jacobi, solve

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        spec = laplace_jacobi(2)
        x0 = jnp.stack([jnp.zeros((16, 16)),
                        0.5 * jnp.ones((16, 16))]).astype(jnp.float32)
        d = solve(spec, x0, backend="halo", mesh=mesh, bc=1.0,
                  rtol=1e-6, check_every=10, max_iters=2000)
        s = solve(spec, x0, backend="reference", bc=1.0,
                  rtol=1e-6, check_every=10, max_iters=2000)
        assert d.converged.all()
        assert np.array_equal(d.iterations, s.iterations)
        err = float(jnp.abs(d.x - s.x).max())
        assert err < 1e-5, err
        print("batched-dist ok", list(map(int, d.iterations)))
        """)
        assert "batched-dist ok" in out
