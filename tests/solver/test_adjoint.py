"""Adjoint-solve tests: the differentiable fixed point (core/adjoint.py).

Three layers of pinning:

  * algebra — tap reflection is a true transpose (⟨Sx, u⟩ = ⟨x, S^T u⟩ for
    random fields) and an involution (transposing twice round-trips);
  * gradients — ``jax.grad`` through ``implicit_solve`` matches central
    finite differences for every differentiable operand (weight fields,
    source, boundary value) on every DIFF backend;
  * structure — batched gradients equal per-instance loop gradients, the
    x0 gradient is exactly zero, and a 5000-iteration fixed-length solve
    differentiates without unrolling (the O(1)-memory property: reverse
    through a ``lax.while_loop`` would fail outright).

FD checks run in float32, so epsilons are chosen where the central-
difference truncation error and the 1e-7 rounding noise cross (~1e-2 for
O(1) losses); tolerances are rtol 1e-3 with a small atol floor.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DIFF_BACKENDS,
    DirichletBC,
    apply_stencil,
    heterogeneous_jacobi,
    implicit_solve,
    jacobi_reference,
    laplace_jacobi,
    transpose_fields,
    transpose_spec,
    variable_coefficient,
)

RNG = np.random.default_rng(20260809)

GRID = (8, 9)


def _hetero_spec(grid=GRID):
    return heterogeneous_jacobi(1.0 + 9.0 * RNG.random(grid))


def _fd_grad(f, x, eps):
    """Central finite-difference gradient of scalar f at concrete x."""
    x = np.asarray(x, np.float64)
    g = np.zeros_like(x)
    it = np.nditer(x, flags=["multi_index"])
    for _ in it:
        idx = it.multi_index
        xp, xm = x.copy(), x.copy()
        xp[idx] += eps
        xm[idx] -= eps
        g[idx] = (float(f(jnp.asarray(xp, jnp.float32)))
                  - float(f(jnp.asarray(xm, jnp.float32)))) / (2 * eps)
    return g


class TestTranspose:
    def test_pairing_identity_scalar_taps(self):
        spec = laplace_jacobi(2)
        x = jnp.asarray(RNG.standard_normal(GRID), jnp.float32)
        u = jnp.asarray(RNG.standard_normal(GRID), jnp.float32)
        lhs = jnp.vdot(apply_stencil(x, spec), u)
        rhs = jnp.vdot(x, apply_stencil(u, transpose_spec(spec)))
        np.testing.assert_allclose(lhs, rhs, rtol=1e-5)

    def test_pairing_identity_variable_taps(self):
        spec = _hetero_spec()
        x = jnp.asarray(RNG.standard_normal(GRID), jnp.float32)
        u = jnp.asarray(RNG.standard_normal(GRID), jnp.float32)
        lhs = jnp.vdot(apply_stencil(x, spec), u)
        rhs = jnp.vdot(x, apply_stencil(u, transpose_spec(spec)))
        np.testing.assert_allclose(lhs, rhs, rtol=1e-5)

    def test_double_transpose_round_trips(self):
        # Offsets round-trip exactly; fields round-trip up to the "dead"
        # border entries (weights whose reads fall outside the grid never
        # contribute, and transposition zero-fills exactly those) — so the
        # double transpose must equal the original *as an operator*.
        spec = _hetero_spec()
        back = transpose_spec(transpose_spec(spec))
        assert [o for o, _ in back.taps] == [o for o, _ in spec.taps]
        x = jnp.asarray(RNG.standard_normal(GRID), jnp.float32)
        np.testing.assert_array_equal(np.asarray(apply_stencil(x, spec)),
                                      np.asarray(apply_stencil(x, back)))

    def test_transpose_fields_matches_transpose_spec(self):
        # The traced field-stack permutation must agree with the numpy
        # spec-level transposition tap for tap.
        spec = variable_coefficient(
            laplace_jacobi(2),
            {(0, 1): 0.2 + 0.1 * RNG.random(GRID),
             (1, 0): 0.2 + 0.1 * RNG.random(GRID)})
        stack = jnp.asarray(spec.field_stack())
        traced = transpose_fields(spec, stack)
        baked = transpose_spec(spec).field_stack()
        np.testing.assert_allclose(np.asarray(traced), np.asarray(baked),
                                   atol=0)

    def test_pairing_identity_asymmetric_offsets(self):
        # A one-sided (upwind-like) spec: transposition must handle taps
        # whose reflections are not themselves in the spec.
        spec = variable_coefficient(
            laplace_jacobi(2), {(1, 1): 0.1 + 0.05 * RNG.random(GRID)})
        x = jnp.asarray(RNG.standard_normal(GRID), jnp.float32)
        u = jnp.asarray(RNG.standard_normal(GRID), jnp.float32)
        lhs = jnp.vdot(apply_stencil(x, spec), u)
        rhs = jnp.vdot(x, apply_stencil(u, transpose_spec(spec)))
        np.testing.assert_allclose(lhs, rhs, rtol=1e-5)


class TestForwardAgreement:
    """implicit_solve's forward pass is the ordinary solve."""

    @pytest.mark.parametrize("backend", ["reference", "dense", "conv"])
    def test_matches_reference_fixed_point(self, backend):
        spec = _hetero_spec()
        src = jnp.asarray(0.1 * RNG.standard_normal(GRID), jnp.float32)
        out = implicit_solve(spec, jnp.zeros(GRID, jnp.float32),
                             fields=jnp.asarray(spec.field_stack()),
                             source=src, backend=backend, rtol=1e-7,
                             max_iters=4000)
        # Oracle: hand-iterate the masked update with the reference step.
        x = jnp.zeros(GRID, jnp.float32)
        m = jnp.zeros(GRID).at[1:-1, 1:-1].set(1.0)
        for _ in range(4000):
            x = m * (apply_stencil(x, spec) + src)
        np.testing.assert_allclose(out, x, atol=1e-5)

    def test_rejects_non_differentiable_backend(self):
        with pytest.raises(ValueError, match="differentiable"):
            implicit_solve(laplace_jacobi(2), jnp.zeros(GRID, jnp.float32),
                           backend="pallas_fused")

    def test_auto_backend_is_differentiable(self):
        for nd, grid in ((1, (33,)), (2, GRID)):
            out = implicit_solve(laplace_jacobi(nd),
                                 jnp.zeros(grid, jnp.float32), bc_value=1.0,
                                 rtol=1e-6)
            assert out.shape == grid


class TestGradientsVsFiniteDifferences:
    """jax.grad through the adjoint == central FD, every operand x backend."""

    EPS = 1e-2
    # atol floors the check for near-zero entries, where the f32 loss
    # rounding (~loss * 1e-7 / 2eps) dominates the FD estimate.
    TOL = dict(rtol=1e-3, atol=2e-3)

    def _solve_kwargs(self, backend):
        return dict(backend=backend, rtol=1e-7, max_iters=4000)

    @pytest.mark.parametrize("backend", ["reference", "dense", "conv"])
    def test_weight_field_gradient(self, backend):
        spec = _hetero_spec()
        src = jnp.asarray(0.3 * RNG.standard_normal(GRID), jnp.float32)
        tgt = jnp.asarray(RNG.standard_normal(GRID), jnp.float32)
        kw = self._solve_kwargs(backend)

        def loss(fields):
            x = implicit_solve(spec, jnp.zeros(GRID, jnp.float32),
                               fields=fields, source=src, **kw)
            return jnp.sum((x - tgt) ** 2)

        f0 = jnp.asarray(spec.field_stack())
        got = np.asarray(jax.grad(loss)(f0))
        want = _fd_grad(loss, f0, self.EPS)
        np.testing.assert_allclose(got, want, **self.TOL)

    @pytest.mark.parametrize("backend", ["reference", "dense", "conv"])
    def test_source_gradient(self, backend):
        spec = laplace_jacobi(2)
        tgt = jnp.asarray(RNG.standard_normal(GRID), jnp.float32)
        kw = self._solve_kwargs(backend)

        def loss(src):
            x = implicit_solve(spec, jnp.zeros(GRID, jnp.float32),
                               source=src, bc_value=0.5, **kw)
            return jnp.sum((x - tgt) ** 2)

        s0 = jnp.asarray(0.3 * RNG.standard_normal(GRID), jnp.float32)
        got = np.asarray(jax.grad(loss)(s0))
        want = _fd_grad(loss, s0, self.EPS)
        np.testing.assert_allclose(got, want, **self.TOL)

    @pytest.mark.parametrize("backend", ["reference", "dense", "conv"])
    def test_scalar_bc_gradient(self, backend):
        spec = _hetero_spec()
        tgt = jnp.asarray(RNG.standard_normal(GRID), jnp.float32)
        kw = self._solve_kwargs(backend)
        fields = jnp.asarray(spec.field_stack())

        def loss(bc):
            x = implicit_solve(spec, jnp.zeros(GRID, jnp.float32),
                               fields=fields, bc_value=bc, **kw)
            return jnp.sum((x - tgt) ** 2)

        got = float(jax.grad(loss)(jnp.float32(0.7)))
        eps = self.EPS
        want = (float(loss(jnp.float32(0.7 + eps)))
                - float(loss(jnp.float32(0.7 - eps)))) / (2 * eps)
        np.testing.assert_allclose(got, want, rtol=1e-3)

    def test_gradients_through_1d_dense(self):
        spec = laplace_jacobi(1)
        n = 17
        tgt = jnp.asarray(RNG.standard_normal(n), jnp.float32)

        def loss(src):
            x = implicit_solve(spec, jnp.zeros(n, jnp.float32), source=src,
                               backend="dense", rtol=1e-7, max_iters=2000)
            return jnp.sum((x - tgt) ** 2)

        s0 = jnp.asarray(0.3 * RNG.standard_normal(n), jnp.float32)
        got = np.asarray(jax.grad(loss)(s0))
        want = _fd_grad(loss, s0, self.EPS)
        np.testing.assert_allclose(got, want, **self.TOL)


class TestStructure:
    def test_x0_gradient_is_exactly_zero(self):
        spec = laplace_jacobi(2)

        def loss(x0):
            return jnp.sum(implicit_solve(spec, x0, bc_value=1.0, rtol=1e-6,
                                          max_iters=2000) ** 2)

        g = jax.grad(loss)(jnp.asarray(RNG.standard_normal(GRID), jnp.float32))
        np.testing.assert_array_equal(np.asarray(g), 0.0)

    def test_batched_grad_equals_per_instance_loop(self):
        spec = _hetero_spec()
        f0 = jnp.asarray(spec.field_stack())
        srcs = jnp.asarray(0.3 * RNG.standard_normal((3, *GRID)), jnp.float32)
        tgts = jnp.asarray(RNG.standard_normal((3, *GRID)), jnp.float32)

        def batched(fields):
            x = implicit_solve(spec, jnp.zeros((3, *GRID), jnp.float32),
                               fields=fields, source=srcs, backend="conv",
                               rtol=1e-7, max_iters=3000)
            return jnp.sum((x - tgts) ** 2)

        def single(fields, i):
            x = implicit_solve(spec, jnp.zeros(GRID, jnp.float32),
                               fields=fields, source=srcs[i], backend="conv",
                               rtol=1e-7, max_iters=3000)
            return jnp.sum((x - tgts[i]) ** 2)

        g_batched = jax.grad(batched)(f0)
        g_loop = sum(jax.grad(lambda f, i=i: single(f, i))(f0)
                     for i in range(3))
        np.testing.assert_allclose(np.asarray(g_batched), np.asarray(g_loop),
                                   rtol=2e-4, atol=1e-5)

    def test_shared_source_grad_sums_over_batch(self):
        spec = laplace_jacobi(2)
        src = jnp.asarray(0.3 * RNG.standard_normal(GRID), jnp.float32)

        def shared(s):
            x = implicit_solve(spec, jnp.zeros((4, *GRID), jnp.float32),
                               source=s, rtol=1e-7, max_iters=2000)
            return jnp.sum(x ** 2)

        def batched(s):
            x = implicit_solve(spec, jnp.zeros((4, *GRID), jnp.float32),
                               source=jnp.broadcast_to(s, (4, *GRID)),
                               rtol=1e-7, max_iters=2000)
            return jnp.sum(x ** 2)

        g_shared = jax.grad(shared)(src)
        g_sum = jax.grad(batched)(src)
        np.testing.assert_allclose(np.asarray(g_shared), np.asarray(g_sum),
                                   rtol=1e-5, atol=1e-6)

    def test_five_thousand_iteration_fixed_solve_differentiates(self):
        # The O(1)-memory property: a fixed-length 5000-iteration solve
        # (rtol=None -> run exactly max_iters steps) reverse-differentiates
        # through one adjoint solve.  Unrolling would build a 5000-step
        # graph; reverse through lax.while_loop would raise outright.
        spec = laplace_jacobi(2)
        grid = (6, 6)

        def loss(src):
            x = implicit_solve(spec, jnp.zeros(grid, jnp.float32),
                               source=src, rtol=None, atol=None,
                               max_iters=5000, backend="conv")
            return jnp.sum(x ** 2)

        s0 = jnp.asarray(0.3 * RNG.standard_normal(grid), jnp.float32)
        g = jax.grad(loss)(s0)
        want = _fd_grad(loss, s0, 1e-2)
        np.testing.assert_allclose(np.asarray(g), want, rtol=1e-3, atol=2e-4)

    def test_jit_grad_composes(self):
        spec = _hetero_spec()
        f0 = jnp.asarray(spec.field_stack())

        @jax.jit
        def g(fields):
            def loss(f):
                x = implicit_solve(spec, jnp.zeros(GRID, jnp.float32),
                                   fields=f, bc_value=1.0, rtol=1e-6,
                                   max_iters=2000)
                return jnp.sum(x ** 2)
            return jax.grad(loss)(fields)

        eager = jax.grad(lambda f: jnp.sum(implicit_solve(
            spec, jnp.zeros(GRID, jnp.float32), fields=f, bc_value=1.0,
            rtol=1e-6, max_iters=2000) ** 2))(f0)
        np.testing.assert_allclose(np.asarray(g(f0)), np.asarray(eager),
                                   rtol=1e-5, atol=1e-7)
