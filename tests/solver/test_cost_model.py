"""Cost-model regression tier: the roofline model's ordering must stay
consistent with (a) the paper's §4 accounting and (b) the timings actually
recorded on this host in ``BENCH_stencil.json`` — so silent roofline drift
(constants edited, FLOP accounting broken, auto picking a regressed backend)
gets caught by CI instead of by a slow benchmark run.
"""
import json
import os

import pytest

from repro.core import choose_backend, laplace_jacobi
from repro.core.plan import DEVICE_PROFILES, estimate_seconds
from repro.core.solver import select_fuse

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
BENCH_PATH = os.path.join(REPO, "BENCH_stencil.json")

TABLE1_GRID = (64, 64)
TABLE1_ITERS = 100


def _load_bench() -> dict:
    if not os.path.exists(BENCH_PATH):
        pytest.skip("no BENCH_stencil.json recorded on this host "
                    "(run scripts/ci.sh)")
    with open(BENCH_PATH) as f:
        data = json.load(f)
    if "solver" not in data:
        pytest.skip("BENCH_stencil.json predates the solver-metrics schema "
                    "(schema >= 2); re-run scripts/ci.sh")
    return data


class TestRooflineModel:
    """Analytic assertions — no recorded artifact needed."""

    def test_dense_much_costlier_than_conv(self):
        # Paper §4: 8191 vs 17 FLOPs/point, plus the N^2 matrix re-stream.
        spec = laplace_jacobi(2)
        cpu = DEVICE_PROFILES["cpu"]
        dense = estimate_seconds("dense", spec, TABLE1_GRID, TABLE1_ITERS, cpu)
        conv = estimate_seconds("conv", spec, TABLE1_GRID, TABLE1_ITERS, cpu)
        assert dense > 10 * conv, (dense, conv)

    def test_auto_picks_conv_for_fp32_table1_shape_on_cpu(self):
        name, costs = choose_backend(laplace_jacobi(2), TABLE1_GRID,
                                     iters=TABLE1_ITERS, device_kind="cpu")
        assert name == "conv", costs

    def test_fuse_depth_pricing_is_monotone_while_memory_bound(self):
        # On the TPU profile a large 2D Jacobi is HBM-bound: each doubling of
        # the fuse depth halves traffic and must price cheaper.
        spec = laplace_jacobi(2)
        tpu = DEVICE_PROFILES["tpu"]
        ests = [estimate_seconds("pallas_fused", spec, (512, 512), 64, tpu,
                                 fuse=f) for f in (1, 2, 4, 8)]
        assert ests == sorted(ests, reverse=True), ests

    def test_fuse_pricing_includes_rim_recompute(self):
        # Deeper fusion is NOT free: compute time must grow with depth even
        # as memory time shrinks (the trapezoid redundancy factor).
        from repro.kernels.tiling import fuse_redundancy
        r1 = fuse_redundancy((64, 64), 1, 1)
        r8 = fuse_redundancy((64, 64), 8, 1)
        assert 1.0 <= r1 < r8

    def test_halo_comm_term_scales_with_perimeter(self):
        # The halo communication term is O(perimeter), not O(area): doubling
        # the grid edge (4x the area) must roughly double the per-exchange
        # wire bytes on a fixed mesh.
        from repro.kernels.tiling import halo_exchange_bytes
        small = halo_exchange_bytes((64, 64), 1, 1)
        big = halo_exchange_bytes((128, 128), 1, 1)
        assert 1.9 < big / small < 2.1, (small, big)
        # and the priced totals preserve that ordering on a slow link
        spec = laplace_jacobi(2)
        cpu = DEVICE_PROFILES["cpu"]
        comm = [estimate_seconds("halo", spec, (g, g), 64, cpu,
                                 mesh_shape=(2, 4)) for g in (64, 128, 256)]
        assert comm == sorted(comm), comm

    def test_halo_fuse_pricing_drops_roughly_one_over_fuse(self):
        # Latency-dominated cell (small tile, cpu collective profile): the
        # per-exchange cost amortizes over fuse local steps, so pricing must
        # be monotone decreasing in depth — the communication-avoiding win.
        spec = laplace_jacobi(2)
        cpu = DEVICE_PROFILES["cpu"]
        ests = [estimate_seconds("halo", spec, (64, 64), 16, cpu, fuse=f,
                                 mesh_shape=(2, 4)) for f in (1, 2, 4, 8)]
        assert ests == sorted(ests, reverse=True), ests
        # the drop tracks ~1/fuse while the latency term dominates
        assert ests[1] < 0.75 * ests[0], ests

    def test_halo_fuse1_pricing_keeps_the_legacy_latency_floor(self):
        # fuse=1 on an unsharded (1x1) mesh must reproduce the pre-fusion
        # model exactly: per-iter roofline + 1e-5s of permute latency per
        # iteration — the backward-compatibility anchor for old cost tables.
        spec = laplace_jacobi(2)
        cpu = DEVICE_PROFILES["cpu"]
        body = estimate_seconds("reference", spec, (64, 64), 16, cpu)
        halo = estimate_seconds("halo", spec, (64, 64), 16, cpu)
        assert abs(halo - (body + 1e-5 * 16)) < 1e-12, (halo, body)

    def test_select_fuse_picks_deep_halo_on_latency_dominated_cells(self):
        spec = laplace_jacobi(2)
        f = select_fuse("halo", spec, (64, 64), 16, "cpu", tuned=None,
                        mesh=(2, 4))
        assert f is not None and f > 1, f
        # the depth is clamped to what the local tile can host
        f_small = select_fuse("halo", spec, (8, 8), 16, "cpu", tuned=None,
                              mesh=(2, 4))
        assert f_small is not None and f_small * spec.radius <= 2, f_small

    def test_select_fuse_prefers_depth_on_tpu_not_on_cpu(self):
        spec = laplace_jacobi(2)
        # memory-bound TPU cell: fusion wins until rim recompute crosses the
        # HBM saving (the model finds the crossover, not the deepest depth)
        assert select_fuse("pallas_fused", spec, (512, 512), 16, "tpu") > 1
        # compute-bound CPU cell: fusing only adds rim recompute
        assert select_fuse("pallas", spec, (16, 16), 16, "cpu") == 1
        # non-fusing backends and 3D kernels never fuse
        assert select_fuse("conv", spec, (64, 64), 16, "cpu") is None
        assert select_fuse("pallas", laplace_jacobi(3), (8, 16, 16), 16,
                           "tpu") is None


class TestRecordedTimings:
    """Model vs the measured artifact this host last produced."""

    def test_measured_dense_conv_ratio_matches_model_ordering(self):
        solver = _load_bench()["solver"]
        keys = {k for k in solver}
        dense = next((solver[k] for k in keys if "dense/fp32" in k), None)
        conv = next((solver[k] for k in keys if "/conv/fp32" in k), None)
        if dense is None or conv is None:
            pytest.skip("artifact lacks dense/conv fp32 solver rows")
        measured_ratio = dense["s_per_iter"] / conv["s_per_iter"]
        assert measured_ratio > 10, measured_ratio

        spec = laplace_jacobi(2)
        cpu = DEVICE_PROFILES["cpu"]
        model_ratio = (
            estimate_seconds("dense", spec, TABLE1_GRID, TABLE1_ITERS, cpu)
            / estimate_seconds("conv", spec, TABLE1_GRID, TABLE1_ITERS, cpu))
        assert model_ratio > 10, model_ratio

    def test_recorded_auto_pick_matches_current_model(self):
        data = _load_bench()
        auto_keys = [k for k in data["us_per_call"] if "/auto=" in k]
        if not auto_keys:
            pytest.skip("artifact lacks an auto row")
        recorded = auto_keys[0].split("auto=")[1].split("/")[0]
        name, _ = choose_backend(laplace_jacobi(2), TABLE1_GRID,
                                 iters=TABLE1_ITERS, device_kind="cpu")
        assert recorded == name, (recorded, name)

    def test_solver_rows_have_stable_schema(self):
        data = _load_bench()
        assert data.get("schema", 0) >= 2
        for name, row in data["solver"].items():
            assert {"mode", "iters", "s_per_iter"} <= set(row), (name, row)
            assert row["iters"] >= 1
            assert row["s_per_iter"] > 0
            if row["mode"] == "converged":
                assert {"residual", "converged", "backend"} <= set(row), name
