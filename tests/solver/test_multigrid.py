"""Multigrid-tier tests: V-cycle contraction, agreement with the plain
solver engine, red-black sweep semantics, and the work-reduction acceptance
criterion vs single-level Jacobi.

The headline numbers: on an odd grid the V-cycle contracts the residual by
better than 4x per cycle (textbook multigrid behaviour); on the paper's
Table-1 64x64 grid — whose even extent leaves the last fine row
unrepresented on coarse levels, degrading contraction — it still reaches
the solver's 1e-5 convergence target in >= 10x fewer fine-grid work units
than the single-level Jacobi solve.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DirichletBC,
    Multigrid,
    laplace_jacobi,
    heterogeneous_jacobi,
    make_plan,
    multigrid_solve,
    red_black_step,
    solve,
)
from repro.core.multigrid import _parity_mask

RNG = np.random.default_rng(20260802)


class TestVCycleContraction:
    """Satellite (a): per-cycle residual contraction beats a fixed factor."""

    def test_odd_grid_contraction(self):
        # 65x65: every level boundary coincides with a coarse point, so the
        # V-cycle shows textbook grid-independent contraction.
        x0 = jnp.asarray(RNG.standard_normal((65, 65)), jnp.float32)
        res = multigrid_solve(laplace_jacobi(2), x0, bc=1.5, rtol=1e-5)
        assert res.converged
        h = res.residual_history
        assert len(h) >= 2
        ratios = h[1:] / h[:-1]
        # Observed ~0.03; assert a conservative fixed factor.
        assert np.all(ratios < 0.25), ratios

    def test_even_grid_still_contracts(self):
        # 64x64 coarsens to 32 with the last fine row unrepresented on the
        # coarse levels; contraction degrades but must stay bounded < 1.
        x0 = jnp.asarray(RNG.standard_normal((64, 64)), jnp.float32)
        res = multigrid_solve(laplace_jacobi(2), x0, bc=1.5, rtol=1e-5)
        assert res.converged
        ratios = res.residual_history[1:] / res.residual_history[:-1]
        assert np.all(ratios < 0.7), ratios

    def test_level_hierarchy_shapes(self):
        mg = Multigrid(laplace_jacobi(2), (65, 65))
        assert mg.level_shapes == ((65, 65), (33, 33), (17, 17), (9, 9),
                                   (5, 5))
        mg = Multigrid(laplace_jacobi(2), (64, 64))
        assert mg.level_shapes == ((64, 64), (32, 32), (16, 16), (8, 8))


class TestAgreementWithSolver:
    """Satellite (b): the multigrid answer is the solver engine's answer."""

    def test_matches_plain_solve(self):
        n = 33
        spec = laplace_jacobi(2)
        x0 = jnp.zeros((n, n), jnp.float32)
        jac = solve(spec, x0, bc=1.5, rtol=1e-6, max_iters=50_000)
        assert jac.converged
        mg = multigrid_solve(spec, x0, bc=1.5, rtol=1e-6)
        assert mg.converged
        rel = float(jnp.linalg.norm(mg.x - jac.x) / jnp.linalg.norm(jac.x))
        assert rel < 1e-3, rel

    def test_constant_bc_fixed_point_is_constant(self):
        # Laplace with u=c on the whole shell has the exact fixed point
        # u == c; multigrid must land on it from any start.
        x0 = jnp.asarray(RNG.standard_normal((33, 33)), jnp.float32)
        res = multigrid_solve(laplace_jacobi(2), x0, bc=2.0, rtol=1e-6)
        assert res.converged
        np.testing.assert_allclose(np.asarray(res.x), 2.0, atol=1e-4)

    @pytest.mark.slow
    def test_matches_solve_variable_coefficient(self):
        n = 33
        kappa = 1.0 + 9.0 * RNG.random((n, n)).astype(np.float32)
        spec = heterogeneous_jacobi(kappa)
        x0 = jnp.zeros((n, n), jnp.float32)
        jac = solve(spec, x0, bc=1.0, rtol=1e-6, max_iters=50_000)
        assert jac.converged
        mg = multigrid_solve(spec, x0, bc=1.0, rtol=1e-6)
        assert mg.converged
        rel = float(jnp.linalg.norm(mg.x - jac.x) / jnp.linalg.norm(jac.x))
        assert rel < 1e-3, rel


class TestRedBlack:
    """Satellite (c): red-black sweep == two masked half-sweeps, bitwise."""

    def test_sweep_is_two_masked_half_sweeps(self):
        n = 17
        spec = laplace_jacobi(2)
        plan = make_plan(spec, (n, n), backend="reference", bc=1.5, iters=1)
        u = jnp.asarray(RNG.standard_normal((n, n)), jnp.float32)
        u = DirichletBC(1.5).set_boundary(u)

        swept = red_black_step(u, plan)

        red = jnp.asarray(_parity_mask((n, n)))
        manual = jnp.where(red, plan(u), u)
        manual = jnp.where(red, manual, plan(manual))
        np.testing.assert_array_equal(np.asarray(swept), np.asarray(manual))

    def test_sweep_with_source_term(self):
        n = 17
        spec = laplace_jacobi(2)
        plan = make_plan(spec, (n, n), backend="reference", bc=0.0, iters=1)
        mask = DirichletBC(0.0).interior_mask((n, n))
        g = jnp.asarray(RNG.standard_normal((n, n)), jnp.float32)
        u = jnp.asarray(RNG.standard_normal((n, n)), jnp.float32)

        swept = red_black_step(u, plan, g=g, mask=mask)

        red = jnp.asarray(_parity_mask((n, n)))
        manual = jnp.where(red, plan(u) + mask * g, u)
        manual = jnp.where(red, manual, plan(manual) + mask * g)
        np.testing.assert_array_equal(np.asarray(swept), np.asarray(manual))

    def test_rb_exact_gauss_seidel_property(self):
        # For a star stencil, red points read only black neighbours: after
        # the red half-sweep, a second red half-sweep is a no-op.
        n = 17
        spec = laplace_jacobi(2)
        plan = make_plan(spec, (n, n), backend="reference", bc=0.5, iters=1)
        u = DirichletBC(0.5).set_boundary(
            jnp.asarray(RNG.standard_normal((n, n)), jnp.float32))
        red = jnp.asarray(_parity_mask((n, n)))
        once = jnp.where(red, plan(u), u)
        twice = jnp.where(red, plan(once), once)
        np.testing.assert_allclose(np.asarray(once), np.asarray(twice),
                                   atol=1e-6)


class TestWorkReduction:
    """Satellite (d): >= 10x fewer fine-grid work units than Jacobi."""

    @pytest.mark.slow
    def test_table1_grid_beats_jacobi_10x(self):
        # Paper Table-1 shape (64x64), solver-default criterion rtol=1e-5.
        spec = laplace_jacobi(2)
        x0 = jnp.asarray(RNG.standard_normal((64, 64)), jnp.float32)
        jac = solve(spec, x0, bc=1.5, rtol=1e-5, max_iters=20_000)
        assert jac.converged
        mg = multigrid_solve(spec, x0, bc=1.5, rtol=1e-5)
        assert mg.converged
        # One Jacobi iteration == 1.0 fine-grid work unit by construction.
        assert mg.work_units * 10 <= jac.iterations, (
            mg.work_units, jac.iterations)

    def test_work_accounting_is_consistent(self):
        mg = Multigrid(laplace_jacobi(2), (65, 65))
        res = mg.solve(jnp.zeros((65, 65), jnp.float32))
        assert res.work_per_cycle == mg.work_per_cycle
        np.testing.assert_allclose(res.work_units,
                                   res.cycles * res.work_per_cycle)
        # A V-cycle is a small constant number of fine-grid sweeps.
        assert 5.0 < mg.work_per_cycle < 40.0


class TestMultigridGeneral:
    @pytest.mark.slow
    def test_3d_converges(self):
        x0 = jnp.asarray(RNG.standard_normal((17, 17, 17)), jnp.float32)
        res = multigrid_solve(laplace_jacobi(3), x0, bc=0.5, rtol=1e-5)
        assert res.converged
        assert res.level_shapes[0] == (17, 17, 17)
        assert len(res.level_shapes) >= 2

    def test_jacobi_smoother_converges(self):
        x0 = jnp.asarray(RNG.standard_normal((33, 33)), jnp.float32)
        res = multigrid_solve(laplace_jacobi(2), x0, bc=1.0, rtol=1e-5,
                              smoother="jacobi")
        assert res.converged

    def test_fixed_cycle_mode(self):
        res = multigrid_solve(laplace_jacobi(2),
                              jnp.zeros((33, 33), jnp.float32), bc=1.0,
                              rtol=None, atol=None, max_cycles=3)
        assert res.cycles == 3 and not res.converged
        assert len(res.residual_history) == 3

    def test_batched_input_rejected(self):
        mg = Multigrid(laplace_jacobi(2), (33, 33))
        with pytest.raises(ValueError, match="batched"):
            mg.solve(jnp.zeros((2, 33, 33), jnp.float32))

    def test_tiny_grid_rejected(self):
        with pytest.raises(ValueError, match="min_size"):
            Multigrid(laplace_jacobi(2), (4, 4))

    def test_bad_smoother_rejected(self):
        with pytest.raises(ValueError, match="smoother"):
            Multigrid(laplace_jacobi(2), (33, 33), smoother="sor")
