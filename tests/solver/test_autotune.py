"""Autotuner tier: the measured tuned-table dispatch contract.

Pins down (a) measured entries beating the roofline in ``choose_backend`` /
``make_plan`` / ``select_fuse``, (b) the explicit roofline fallback when no
entry applies, (c) corrupt / stale tables degrading with a warning instead
of crashing dispatch, (d) interpret-mode measurements never winning a cell,
(e) the extended fusion geometry (rim="resident") staying exact, and (f) the
hillclimb harness no longer clobbering a caller's XLA_FLAGS at import time.
"""
import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DirichletBC,
    choose_backend,
    laplace_jacobi,
    make_plan,
    stencil_apply,
)
from repro.core.autotune import (
    SCHEMA_VERSION,
    TableError,
    TunedEntry,
    TunedTable,
    bucket_distance,
    dtype_key,
    set_default_tuned_table,
    shape_bucket,
    spec_family,
    validate_table,
)
from repro.core.reference import jacobi_reference
from repro.core.solver import select_fuse

REPO = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))

SPEC = laplace_jacobi(2)
GRID = (64, 64)
FAM = spec_family(SPEC)
F32 = dtype_key(jnp.float32)


def entry(backend, us, *, fuse=1, block_h=None, rim=None, interpreted=False,
          device_kind="cpu", bucket=GRID, family=FAM, dtype=F32):
    return TunedEntry(device_kind=device_kind, family=family, bucket=bucket,
                      dtype=dtype, backend=backend, us_per_iter=us, fuse=fuse,
                      block_h=block_h, rim=rim, interpreted=interpreted)


@pytest.fixture(autouse=True)
def _isolate_default_table(monkeypatch, tmp_path):
    """Point the process-wide default table at a nonexistent file so these
    tests never read (or are polluted by) the committed artifact."""
    monkeypatch.setenv("REPRO_TUNED_TABLE", str(tmp_path / "absent.json"))
    set_default_tuned_table(None)
    yield
    set_default_tuned_table(None)


# ---------------------------------------------------------------------------
# Cell keys
# ---------------------------------------------------------------------------

class TestCellKeys:
    def test_family_is_structural(self):
        assert FAM == "2d/r1/t4"
        assert spec_family(laplace_jacobi(3)) == "3d/r1/t6"
        from repro.core import heterogeneous_jacobi
        k = np.ones(GRID, np.float32)
        assert spec_family(heterogeneous_jacobi(k)).endswith("/var")

    def test_shape_bucket_rounds_up_to_pow2(self):
        assert shape_bucket((60, 64)) == (64, 64)
        assert shape_bucket((65, 1)) == (128, 1)

    def test_bucket_distance(self):
        assert bucket_distance((64, 64), (64, 64)) == 0.0
        assert bucket_distance((64, 64), (128, 64)) == 1.0
        assert bucket_distance((64, 64), (64, 64, 64)) == float("inf")


# ---------------------------------------------------------------------------
# Measured entries beat the roofline
# ---------------------------------------------------------------------------

class TestMeasuredPreference:
    def test_choose_backend_prefers_measured_entry_over_roofline(self):
        # The roofline on CPU picks conv for this cell; a measured table
        # claiming a compiled pallas_fused schedule is faster must override.
        roof_name, _ = choose_backend(SPEC, GRID, iters=100,
                                      device_kind="cpu", tuned=None)
        assert roof_name == "conv"
        table = TunedTable((entry("conv", 100.0),
                            entry("pallas_fused", 5.0, fuse=8, block_h=64)))
        name, costs = choose_backend(SPEC, GRID, iters=100,
                                     device_kind="cpu", tuned=table)
        assert name == "pallas_fused"
        # the returned cost table is the measured one, argmin included
        assert costs[name] == min(costs.values())
        assert costs["pallas_fused"] == pytest.approx(5e-6 * 100)

    def test_make_plan_inherits_tuned_schedule(self):
        table = TunedTable((entry("conv", 100.0),
                            entry("pallas_fused", 5.0, fuse=8, block_h=64,
                                  rim="trapezoid")))
        plan = make_plan(SPEC, GRID, backend="auto", bc=1.0, iters=16,
                         device_kind="cpu", tuned=table)
        assert plan.source == "tuned"
        assert plan.backend == "pallas_fused"
        assert plan.fuse == 8 and plan.rim == "trapezoid"

    def test_tuned_fuse_not_inherited_when_it_does_not_divide(self):
        table = TunedTable((entry("pallas_fused", 5.0, fuse=8),))
        plan = make_plan(SPEC, GRID, backend="auto", bc=1.0, iters=12,
                         device_kind="cpu", tuned=table)
        assert plan.backend == "pallas_fused"
        assert 12 % plan.fuse == 0  # fell back to a legal depth

    def test_solver_plan_reports_choice_source(self):
        from repro.core import Solver
        table = TunedTable((entry("conv", 10.0),))
        s = Solver(SPEC, GRID, bc=1.0, rtol=None, atol=None, max_iters=4,
                   device_kind="cpu", tuned=table)
        assert s.backend == "conv" and s.plan.source == "tuned"
        s = Solver(SPEC, GRID, bc=1.0, rtol=None, atol=None, max_iters=4,
                   device_kind="cpu", tuned=None)
        assert s.plan.source == "roofline"
        s = Solver(SPEC, GRID, backend="conv", bc=1.0, rtol=None, atol=None,
                   max_iters=4, device_kind="cpu", tuned=None)
        assert s.plan.source == "explicit"

    def test_select_fuse_takes_measured_depth_with_clamping(self):
        table = TunedTable((entry("pallas_fused", 5.0, fuse=8),))
        assert select_fuse("pallas_fused", SPEC, GRID, 16, "cpu",
                           tuned=table) == 8
        # clamped down to a divisor of check_every
        assert select_fuse("pallas_fused", SPEC, GRID, 20, "cpu",
                           tuned=table) == 5
        # non-fusing backends stay None regardless of the table
        assert select_fuse("conv", SPEC, GRID, 16, "cpu", tuned=table) is None

    def test_tuned_plan_still_matches_oracle(self):
        table = TunedTable((entry("pallas_fused", 5.0, fuse=4,
                                  rim="resident"),))
        x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 8)),
                        jnp.float32)
        got = stencil_apply(SPEC, x, backend="auto", bc=1.0, iters=4,
                            device_kind="cpu", tuned=table)
        want = jacobi_reference(x, SPEC, DirichletBC(1.0), 4)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-6)


# ---------------------------------------------------------------------------
# Explicit roofline fallback
# ---------------------------------------------------------------------------

class TestRooflineFallback:
    def test_empty_table_matches_disabled_table(self):
        a = choose_backend(SPEC, GRID, iters=100, device_kind="cpu",
                           tuned=TunedTable())
        b = choose_backend(SPEC, GRID, iters=100, device_kind="cpu",
                           tuned=None)
        assert a == b

    def test_far_bucket_falls_back_to_roofline(self):
        # Entry recorded at 64x64; a 4096x4096 query is 12 doublings away —
        # outside the default max_distance — so the roofline decides.
        table = TunedTable((entry("pallas_fused", 5.0, fuse=8),))
        name, _ = choose_backend(SPEC, (4096, 4096), iters=100,
                                 device_kind="cpu", tuned=table)
        roof, _ = choose_backend(SPEC, (4096, 4096), iters=100,
                                 device_kind="cpu", tuned=None)
        assert name == roof == "conv"

    def test_near_bucket_transfers(self):
        table = TunedTable((entry("pallas_fused", 5.0, fuse=8),))
        name, _ = choose_backend(SPEC, (60, 60), iters=8, device_kind="cpu",
                                 tuned=table)  # same bucket
        assert name == "pallas_fused"
        name, _ = choose_backend(SPEC, (100, 100), iters=8,
                                 device_kind="cpu", tuned=table)  # 1 away
        assert name == "pallas_fused"

    def test_wrong_family_or_dtype_misses(self):
        # The entry is keyed (cpu, 2d/r1/t4, fp32): a 3D query or a bf16
        # query must behave exactly as if the table were disabled.
        table = TunedTable((entry("pallas_fused", 5.0),))
        name, _ = choose_backend(laplace_jacobi(3), (8, 16, 16), iters=8,
                                 device_kind="cpu", tuned=table)
        assert name == choose_backend(laplace_jacobi(3), (8, 16, 16),
                                      iters=8, device_kind="cpu",
                                      tuned=None)[0]
        name, _ = choose_backend(SPEC, GRID, iters=8, device_kind="cpu",
                                 dtype=jnp.bfloat16, tuned=table)
        assert name == "conv"


# ---------------------------------------------------------------------------
# Interpret-mode entries never win
# ---------------------------------------------------------------------------

class TestInterpretedExclusion:
    def test_interpreted_entry_cannot_win_cell(self):
        table = TunedTable((entry("pallas", 1.0, interpreted=True),
                            entry("conv", 50.0)))
        name, costs = choose_backend(SPEC, GRID, iters=8, device_kind="cpu",
                                     tuned=table)
        assert name == "conv"
        assert "pallas" not in costs

    def test_only_interpreted_entries_fall_back_to_roofline(self):
        table = TunedTable((entry("pallas", 1.0, interpreted=True),
                            entry("pallas_fused", 1.0, interpreted=True)))
        name, _ = choose_backend(SPEC, GRID, iters=100, device_kind="cpu",
                                 tuned=table)
        assert name == "conv"  # roofline fallback, not interpreted pallas

    def test_table_lookup_skips_interpreted(self):
        table = TunedTable((entry("pallas", 1.0, interpreted=True),
                            entry("conv", 50.0)))
        best = table.lookup("cpu", FAM, GRID, F32)
        assert best is not None and best.backend == "conv"


# ---------------------------------------------------------------------------
# Corrupt / stale artifacts degrade, never crash
# ---------------------------------------------------------------------------

class TestTableRobustness:
    def test_corrupt_json_warns_and_degrades(self, tmp_path):
        p = tmp_path / "TUNED_stencil.json"
        p.write_text("{not json", encoding="utf-8")
        with pytest.warns(UserWarning, match="ignoring tuned table"):
            table = TunedTable.load(str(p))
        assert len(table) == 0
        # dispatch through the bad table still works (roofline fallback)
        name, _ = choose_backend(SPEC, GRID, iters=100, device_kind="cpu",
                                 tuned=table)
        assert name == "conv"

    def test_stale_schema_warns_and_degrades(self, tmp_path):
        p = tmp_path / "TUNED_stencil.json"
        p.write_text(json.dumps({"schema": SCHEMA_VERSION + 1,
                                 "entries": []}), encoding="utf-8")
        with pytest.warns(UserWarning, match="stale or future"):
            table = TunedTable.load(str(p))
        assert len(table) == 0

    def test_missing_file_is_silently_empty(self, tmp_path):
        table = TunedTable.load(str(tmp_path / "nope.json"))
        assert len(table) == 0

    def test_default_table_env_override_survives_corruption(self, monkeypatch,
                                                            tmp_path):
        p = tmp_path / "bad.json"
        p.write_text("[]", encoding="utf-8")
        monkeypatch.setenv("REPRO_TUNED_TABLE", str(p))
        set_default_tuned_table(None)
        with pytest.warns(UserWarning):
            plan = make_plan(SPEC, GRID, backend="auto", bc=1.0, iters=4,
                             device_kind="cpu")  # tuned="default"
        assert plan.source == "roofline"
        assert plan.backend == "conv"

    def test_strict_parse_raises(self):
        with pytest.raises(TableError):
            TunedTable.parse({"schema": 999, "entries": []})
        with pytest.raises(TableError):
            TunedTable.parse({"schema": SCHEMA_VERSION,
                              "entries": [{"bogus": 1}]})

    def test_roundtrip(self, tmp_path):
        table = TunedTable((entry("conv", 50.0),
                            entry("pallas_fused", 5.0, fuse=8, block_h=128,
                                  rim="trapezoid")))
        p = tmp_path / "t.json"
        table.save(str(p))
        back = TunedTable.load(str(p))
        assert sorted(e.backend for e in back.entries) == \
            ["conv", "pallas_fused"]
        assert back.lookup("cpu", FAM, GRID, F32).fuse == 8


# ---------------------------------------------------------------------------
# Table validation (scripts/ci.sh --tune-check)
# ---------------------------------------------------------------------------

class TestValidation:
    def test_valid_table_passes(self):
        table = TunedTable((entry("conv", 50.0),))
        assert validate_table(table.to_json()) == []

    def test_unknown_backend_fails(self):
        data = TunedTable((entry("conv", 50.0),)).to_json()
        data["entries"][0]["backend"] = "tensorcore9000"
        assert any("unknown backend" in e for e in validate_table(data))

    def test_illegal_support_cell_fails(self):
        # conv has no 1D encoding: a 1d family conv entry must fail CI.
        data = TunedTable((entry("conv", 50.0, family="1d/r1/t2",
                                 bucket=(64,)),)).to_json()
        assert any("legal backend_support" in e for e in validate_table(data))

    def test_wrong_schema_fails(self):
        assert validate_table({"schema": 99, "entries": []})

    def test_committed_table_validates(self):
        path = os.path.join(REPO, "TUNED_stencil.json")
        if not os.path.exists(path):
            pytest.skip("no committed TUNED_stencil.json")
        with open(path) as f:
            data = json.load(f)
        assert validate_table(data) == []
        assert len(data["entries"]) >= 1


# ---------------------------------------------------------------------------
# Extended fusion geometry
# ---------------------------------------------------------------------------

class TestMeshKeyedEntries:
    """Halo schedules are tuned per mesh shape and must stay mesh-exact."""

    def test_mesh_roundtrips_and_is_omitted_when_absent(self, tmp_path):
        t = TunedTable()
        t.add(entry("conv", 5.0))
        t.add(TunedEntry(device_kind="cpu", family=FAM, bucket=GRID,
                         dtype=F32, backend="halo", us_per_iter=3.0,
                         fuse=4, mesh=(2, 4)))
        p = tmp_path / "t.json"
        t.save(str(p))
        raw = json.loads(p.read_text())
        by_backend = {e["backend"]: e for e in raw["entries"]}
        assert "mesh" not in by_backend["conv"]
        assert by_backend["halo"]["mesh"] == [2, 4]
        t2 = TunedTable.load(str(p))
        halo = next(e for e in t2.entries if e.backend == "halo")
        assert halo.mesh == (2, 4)

    def test_lookup_filters_on_mesh_shape(self):
        t = TunedTable()
        t.add(TunedEntry(device_kind="cpu", family=FAM, bucket=GRID,
                         dtype=F32, backend="halo", us_per_iter=3.0,
                         fuse=4, mesh=(2, 4)))
        t.add(entry("conv", 5.0))
        # no mesh given: the halo entry is invisible, conv still applies
        assert t.lookup("cpu", FAM, GRID, F32).backend == "conv"
        # matching mesh: the (faster) halo entry wins
        hit = t.lookup("cpu", FAM, GRID, F32, mesh_shape=(2, 4))
        assert hit.backend == "halo" and hit.fuse == 4
        # a different mesh shape must not inherit the timing
        assert t.lookup("cpu", FAM, GRID, F32,
                        mesh_shape=(2, 2)).backend == "conv"

    def test_select_fuse_takes_mesh_matched_halo_depth(self):
        t = TunedTable()
        t.add(TunedEntry(device_kind="cpu", family=FAM, bucket=GRID,
                         dtype=F32, backend="halo", us_per_iter=3.0,
                         fuse=8, mesh=(2, 4)))
        f = select_fuse("halo", SPEC, GRID, 16, "cpu", tuned=t, mesh=(2, 4))
        assert f == 8
        # clamped to a divisor of check_every
        assert select_fuse("halo", SPEC, GRID, 12, "cpu", tuned=t,
                           mesh=(2, 4)) == 6
        # and to the depth the local tile can host: (8, 8) over (2, 4)
        # leaves 4x2 tiles, so the measured 8 collapses to 2
        assert select_fuse("halo", SPEC, (8, 8), 16, "cpu", tuned=t,
                           mesh=(2, 4)) == 2

    def test_halo_schedule_candidates_respect_tile_and_chunk(self):
        from repro.core.autotune import halo_schedule_candidates
        cands = halo_schedule_candidates(SPEC, (64, 64), (2, 4), 16)
        assert [c.fuse for c in cands] == [1, 2, 4, 8]
        assert all(c.backend == "halo" for c in cands)
        # 12-iteration chunks drop the non-dividing depths
        assert [c.fuse for c in
                halo_schedule_candidates(SPEC, (64, 64), (2, 4), 12)] == [1, 2, 4]
        # tiny tiles clamp the sweep; non-tiling grids yield nothing
        assert [c.fuse for c in
                halo_schedule_candidates(SPEC, (8, 8), (2, 4), 16)] == [1, 2]
        assert halo_schedule_candidates(SPEC, (9, 9), (2, 4), 16) == []

    def test_validation_enforces_mesh_discipline(self):
        t = TunedTable()
        t.add(TunedEntry(device_kind="cpu", family=FAM, bucket=GRID,
                         dtype=F32, backend="halo", us_per_iter=3.0,
                         fuse=4, mesh=(2, 4)))
        assert validate_table(t.to_json()) == []
        # halo without a mesh is an invalid artifact
        bare = TunedTable()
        bare.add(entry("halo", 3.0))
        errs = validate_table(bare.to_json())
        assert errs and "mesh" in errs[0]
        # mesh on a single-device backend is equally invalid
        wrong = TunedTable()
        wrong.add(TunedEntry(device_kind="cpu", family=FAM, bucket=GRID,
                             dtype=F32, backend="conv", us_per_iter=3.0,
                             mesh=(2, 2)))
        errs = validate_table(wrong.to_json())
        assert errs and "halo-only" in errs[0]


class TestResidentRim:
    def test_resident_matches_reference_deep_fuse(self):
        # Depths the trapezoid geometry rejects outright on a 33x57 grid.
        from repro.kernels import jacobi2d
        from repro.kernels.ref import jacobi2d_ref
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.standard_normal((2, 33, 57)), jnp.float32)
        got = jacobi2d(x, SPEC, bc_value=1.0, iterations=32, fuse=32,
                       rim="resident")
        want = jacobi2d_ref(x, SPEC, 1.0, 32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)

    def test_resident_rejects_oversized_grids(self):
        from repro.kernels.tiling import resident_fits
        assert resident_fits((64, 64))
        assert not resident_fits((4096, 4096))

    def test_unknown_rim_raises(self):
        with pytest.raises(ValueError, match="rim"):
            from repro.kernels.tiling import fused_block_geometry
            fused_block_geometry(64, 64, 4, 1, rim="mystery")


# ---------------------------------------------------------------------------
# hillclimb harness regressions
# ---------------------------------------------------------------------------

class TestHillclimbEnv:
    def test_import_does_not_clobber_xla_flags(self):
        code = (
            "import os\n"
            "os.environ['XLA_FLAGS'] = '--xla_gpu_autotune_level=0'\n"
            "import benchmarks.hillclimb\n"
            "assert os.environ['XLA_FLAGS'] == "
            "'--xla_gpu_autotune_level=0', os.environ['XLA_FLAGS']\n"
            "print('CLEAN')\n"
        )
        r = subprocess.run([sys.executable, "-c", code], cwd=REPO,
                           env={**os.environ,
                                "PYTHONPATH": os.path.join(REPO, "src")},
                           capture_output=True, text=True, timeout=120)
        assert r.returncode == 0, r.stderr[-2000:]
        assert "CLEAN" in r.stdout

    def test_force_host_devices_appends(self, monkeypatch):
        from benchmarks.hillclimb import _force_host_devices
        monkeypatch.setenv("XLA_FLAGS", "--xla_foo=1")
        _force_host_devices(8)
        assert os.environ["XLA_FLAGS"] == \
            "--xla_foo=1 --xla_force_host_platform_device_count=8"
        # idempotent: an existing device-count flag is left alone
        _force_host_devices(16)
        assert "device_count=8" in os.environ["XLA_FLAGS"]

    def test_roofline_constants_come_from_device_profiles(self):
        import inspect
        from benchmarks import hillclimb
        src = inspect.getsource(hillclimb.run)
        for const in ("197e12", "819e9", "50e9"):
            assert const not in src
        assert "DEVICE_PROFILES" in src
