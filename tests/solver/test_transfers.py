"""Property tests for the multigrid transfer operators.

Restriction (full weighting) and prolongation (linear interpolation) are
plain ``StencilSpec``s applied through raw (zero-padded) plans, so their
algebraic structure is checkable exactly:

  * transpose pairing: ``<P e, x>_fine == 2^ndim * <e, R x>_coarse`` — the
    prolongation stencil is ``2^ndim`` times the restriction stencil, and
    zero-stuffing is the exact adjoint of even-index sampling under zero
    padding;
  * constant-field preservation on the interior (away from the zero-padded
    rim both operators have unit row sums);
  * shape round-tripping across odd/even and non-square grids.

Deterministic sweeps cover a fixed shape set; hypothesis-driven versions of
the same properties run when hypothesis is installed (they skip otherwise —
see tests/_hypothesis_stub.py).
"""
import sys
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_stub import given, settings, st

from repro.core import (
    coarse_shape,
    make_plan,
    prolongation_spec,
    restriction_spec,
)

RNG = np.random.default_rng(20260802)

SHAPES_1D = [(9,), (12,), (33,)]
SHAPES_2D = [(9, 9), (12, 17), (16, 16), (33, 21)]
SHAPES_3D = [(6, 9, 12), (9, 9, 9)]
ALL_SHAPES = SHAPES_1D + SHAPES_2D + SHAPES_3D


def _restrict(x):
    nd = x.ndim
    plan = make_plan(restriction_spec(nd), x.shape, backend="reference",
                     bc=None, iters=1)
    return plan(jnp.asarray(x, jnp.float32))[(slice(None, None, 2),) * nd]


def _prolong(e, fine_shape):
    nd = len(fine_shape)
    stuff = (slice(None, None, 2),) * nd
    full = jnp.zeros(fine_shape, jnp.float32).at[stuff].set(
        jnp.asarray(e, jnp.float32))
    plan = make_plan(prolongation_spec(nd), fine_shape, backend="reference",
                     bc=None, iters=1)
    return plan(full)


def _check_transpose_pairing(fine_shape, rng):
    nd = len(fine_shape)
    cshape = coarse_shape(fine_shape)
    x = rng.standard_normal(fine_shape).astype(np.float32)
    e = rng.standard_normal(cshape).astype(np.float32)
    lhs = float(jnp.sum(_prolong(e, fine_shape) * x))
    rhs = (2.0 ** nd) * float(jnp.sum(jnp.asarray(e) * _restrict(x)))
    scale = max(abs(lhs), abs(rhs), 1.0)
    assert abs(lhs - rhs) / scale < 1e-5, (fine_shape, lhs, rhs)


def _check_constant_preservation(fine_shape):
    nd = len(fine_shape)
    cshape = coarse_shape(fine_shape)

    r = np.asarray(_restrict(np.ones(fine_shape, np.float32)))
    # Coarse interior: coarse i maps to fine 2i with 2i +- 1 in-array.
    interior = tuple(slice(1, (s - 2) // 2 + 1) for s in fine_shape)
    if all(sl.start < sl.stop for sl in interior):
        np.testing.assert_allclose(r[interior], 1.0, atol=1e-6)

    p = np.asarray(_prolong(np.ones(cshape, np.float32), fine_shape))
    # Fine region where interpolation has full coarse support per dim:
    # indices 0 .. 2*(nc-1) - 1 plus the even endpoint 2*(nc-1).
    region = tuple(slice(0, 2 * (nc - 1) + 1) for nc in cshape)
    np.testing.assert_allclose(p[region], 1.0, atol=1e-6)


def _check_shapes(fine_shape, rng):
    cshape = coarse_shape(fine_shape)
    x = rng.standard_normal(fine_shape).astype(np.float32)
    r = _restrict(x)
    assert r.shape == cshape
    p = _prolong(np.asarray(r), fine_shape)
    assert p.shape == fine_shape


class TestTransfersDeterministic:
    @pytest.mark.parametrize("shape", ALL_SHAPES, ids=str)
    def test_transpose_pairing(self, shape):
        _check_transpose_pairing(shape, RNG)

    @pytest.mark.parametrize("shape", ALL_SHAPES, ids=str)
    def test_constant_preservation(self, shape):
        _check_constant_preservation(shape)

    @pytest.mark.parametrize("shape", ALL_SHAPES, ids=str)
    def test_shape_round_trip(self, shape):
        _check_shapes(shape, RNG)

    @pytest.mark.parametrize("ndim", [1, 2, 3])
    def test_prolongation_is_scaled_restriction(self, ndim):
        rk = restriction_spec(ndim).to_kernel()
        pk = prolongation_spec(ndim).to_kernel()
        np.testing.assert_allclose(pk, (2.0 ** ndim) * rk, atol=1e-12)
        # Full weighting has unit total mass.
        np.testing.assert_allclose(rk.sum(), 1.0, atol=1e-12)

    def test_prolongation_interpolates_linearly_1d(self):
        # Zero-stuff + stencil == linear interpolation between coarse points.
        e = np.asarray([0.0, 2.0, 4.0, 6.0], np.float32)
        p = np.asarray(_prolong(e, (7,)))
        np.testing.assert_allclose(p, [0, 1, 2, 3, 4, 5, 6], atol=1e-6)


class TestTransfersHypothesis:
    """Same invariants, hypothesis-driven (skips when not installed)."""

    @settings(max_examples=25, deadline=None)
    @given(h=st.integers(6, 40), w=st.integers(6, 40))
    def test_transpose_pairing_2d(self, h, w):
        _check_transpose_pairing((h, w), np.random.default_rng(h * 100 + w))

    @settings(max_examples=25, deadline=None)
    @given(h=st.integers(6, 40), w=st.integers(6, 40))
    def test_constant_preservation_2d(self, h, w):
        _check_constant_preservation((h, w))

    @settings(max_examples=25, deadline=None)
    @given(h=st.integers(6, 40), w=st.integers(6, 40))
    def test_shape_round_trip_2d(self, h, w):
        _check_shapes((h, w), np.random.default_rng(h * 100 + w))

    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(6, 16))
    def test_transpose_pairing_3d(self, n):
        _check_transpose_pairing((n, n + 1, n + 2),
                                 np.random.default_rng(n))
