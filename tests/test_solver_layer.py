"""The learned-stencil solver layer: the adjoint solve in the training stack.

Pins the ISSUE-9 integration surface: config registration, the ModelApi
contract (init/shapes/dims agree), training through the standard
``make_train_step`` + AdamW machinery (loss must drop on a recoverable
inverse problem), the sharding rules for grid-shaped params, and checkpoint
round-trips for trees holding ``WeightField`` leaves — including restore
under shardings.
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.checkpoint.checkpoint import Checkpointer
from repro.core import WeightField, heterogeneous_jacobi, implicit_solve
from repro.models.model_zoo import build
from repro.models.solver_layer import SolverLayerConfig, solver_loss_fn
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import Sharder
from repro.train.train_step import (
    init_train_state,
    make_train_step,
    state_dims,
    state_shapes,
)

RNG = np.random.default_rng(20260809)


def _batch(cfg, n=4, seed=0):
    rng = np.random.default_rng(seed)
    true_spec = heterogeneous_jacobi(1.0 + 9.0 * rng.random(cfg.grid))
    src = jnp.asarray(rng.standard_normal((n, *cfg.grid)), jnp.float32)
    tgt = implicit_solve(true_spec, jnp.zeros_like(src),
                         fields=jnp.asarray(true_spec.field_stack()),
                         source=src, backend=cfg.backend, rtol=1e-6,
                         max_iters=2 * cfg.max_iters)
    return {"source": src, "target": tgt}


class TestConfigAndApi:
    def test_registered_config_builds(self):
        cfg = get_config("learned-stencil", smoke=True)
        assert cfg.family == "solver"
        api = build(cfg)
        assert api.cfg is cfg

    def test_rejects_non_differentiable_backend(self):
        with pytest.raises(ValueError, match="differentiable"):
            SolverLayerConfig(backend="pallas_fused")

    def test_init_shapes_dims_agree(self):
        api = build(get_config("learned-stencil", smoke=True))
        params = api.init(jax.random.PRNGKey(0))
        shapes = api.shapes()
        dims = api.dims()
        assert jax.tree.structure(params) == jax.tree.structure(shapes)
        for key in ("taps", "bc"):
            assert params[key].shape == shapes[key].shape
            assert len(dims[key]) == params[key].ndim
        # taps start at the uniform-diffusion operator, bc at zero
        cfg = api.cfg
        np.testing.assert_array_equal(np.asarray(params["taps"]),
                                      cfg.init_weight)
        assert float(params["bc"]) == 0.0

    def test_forward_is_the_converged_solve(self):
        cfg = get_config("learned-stencil", smoke=True)
        api = build(cfg)
        params = api.init(jax.random.PRNGKey(0))
        batch = _batch(cfg, n=2)
        out, aux = api.forward(params, batch)
        assert out.shape == batch["source"].shape
        want = implicit_solve(
            heterogeneous_jacobi(np.ones(cfg.grid)),
            jnp.zeros_like(batch["source"]), fields=params["taps"],
            source=batch["source"], bc_value=params["bc"],
            backend=cfg.backend, rtol=cfg.rtol, max_iters=cfg.max_iters)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=0)

    def test_token_entry_points_are_explicitly_absent(self):
        api = build(get_config("learned-stencil", smoke=True))
        with pytest.raises(NotImplementedError, match="steady states"):
            api.prefill(None, None, 0)
        with pytest.raises(NotImplementedError, match="steady states"):
            api.decode_step(None, None, None, 0)
        assert api.cache_shapes(None, 0) == {}
        assert api.cache_dims() == {}


class TestTraining:
    def test_loss_drops_through_the_standard_train_step(self):
        cfg = get_config("learned-stencil", smoke=True)
        api = build(cfg)
        batch = _batch(cfg, n=4)
        state = init_train_state(api, jax.random.PRNGKey(0))
        opt = AdamWConfig(lr=1e-2, warmup_steps=5, total_steps=25,
                          weight_decay=0.0, grad_clip=1.0)
        step = jax.jit(make_train_step(api, None, opt))
        first = float(solver_loss_fn(api, state["params"], batch)[0])
        for _ in range(25):
            state, metrics = step(state, batch)
        last = float(solver_loss_fn(api, state["params"], batch)[0])
        assert last < first / 2, (first, last)
        assert set(metrics) >= {"loss", "mse", "grad_norm", "lr"}

    def test_state_dims_cover_solver_state(self):
        api = build(get_config("learned-stencil", smoke=True))
        dims = state_dims(api)
        shapes = state_shapes(api)
        state = init_train_state(api, jax.random.PRNGKey(1))
        for k in ("params", "m", "v"):
            assert set(dims[k]) == set(state[k]) == {"taps", "bc"}
            for p in ("taps", "bc"):
                assert len(dims[k][p]) == state[k][p].ndim, (k, p)
        assert shapes["params"]["taps"].shape == state["params"]["taps"].shape
        assert shapes["step"].shape == ()


class _FakeMesh:
    def __init__(self, **shape):
        self.shape = shape
        self.axis_names = tuple(shape)


class TestSharding:
    def test_taps_shard_rows_over_data(self):
        sh = Sharder(mesh=_FakeMesh(data=16, model=16), profile="tp")
        spec = sh.spec(("taps", "grid_row", "grid_col"), (4, 32, 32))
        assert spec == P(None, "data", None)

    def test_indivisible_grid_replicates(self):
        sh = Sharder(mesh=_FakeMesh(data=16, model=16), profile="tp")
        spec = sh.spec(("taps", "grid_row", "grid_col"), (4, 12, 14))
        assert spec == P(None, None, None)


class TestCheckpointWeightFields:
    def _tree(self):
        return {
            "spec_fields": WeightField(RNG.random((5, 6)).astype(np.float32)),
            "nested": {"wf": WeightField(RNG.random((3, 3)).astype(np.float32)),
                       "plain": np.arange(4, dtype=np.float32)},
            "scalar": np.float32(2.5),
        }

    def test_weight_field_round_trip_bitwise(self):
        tree = self._tree()
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d)
            ck.save(1, tree)
            step, restored = ck.restore_latest()
        assert step == 1
        assert isinstance(restored["spec_fields"], WeightField)
        assert isinstance(restored["nested"]["wf"], WeightField)
        np.testing.assert_array_equal(restored["spec_fields"].array,
                                      tree["spec_fields"].array)
        np.testing.assert_array_equal(restored["nested"]["wf"].array,
                                      tree["nested"]["wf"].array)
        np.testing.assert_array_equal(restored["nested"]["plain"],
                                      tree["nested"]["plain"])

    def test_weight_field_restore_under_shardings(self):
        # Restore with a shardings tree holding ONE sharding at the
        # WeightField's position: device_put broadcasts it over the wrapped
        # array instead of descending into the pytree node.
        tree = {"wf": WeightField(RNG.random((4, 4)).astype(np.float32)),
                "arr": np.ones((2, 2), np.float32)}
        dev = jax.devices()[0]
        shardings = {"wf": dev, "arr": dev}
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d)
            ck.save(3, tree)
            _, restored = ck.restore_latest(shardings)
        assert isinstance(restored["wf"], WeightField)
        assert isinstance(restored["wf"].values, jax.Array)
        np.testing.assert_array_equal(np.asarray(restored["wf"].values),
                                      tree["wf"].array)

    def test_train_state_with_solver_params_round_trips(self):
        cfg = get_config("learned-stencil", smoke=True)
        api = build(cfg)
        state = init_train_state(api, jax.random.PRNGKey(0))
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d)
            ck.save(7, state)
            step, restored = ck.restore_latest()
        assert step == 7
        for k in ("params", "m", "v"):
            np.testing.assert_array_equal(
                np.asarray(restored[k]["taps"]),
                np.asarray(state[k]["taps"]), err_msg=k)
        assert int(restored["step"]) == int(state["step"])
