"""Shared helpers for the test tree."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture
def run_with_devices():
    """Run a Python snippet in a subprocess under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=n``.

    The flag must be set before jax imports, and the main pytest process must
    keep its single-device view — hence the subprocess.  Returns the
    subprocess's stdout; asserts it exited cleanly.
    """
    def run(src: str, n: int = 8, timeout: int = 900) -> str:
        code = (
            "import os\n"
            f"os.environ['XLA_FLAGS'] = "
            f"'--xla_force_host_platform_device_count={n}'\n"
            f"import sys; sys.path.insert(0, {os.path.join(REPO, 'src')!r})\n"
            + textwrap.dedent(src)
        )
        r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                           text=True, timeout=timeout)
        assert r.returncode == 0, \
            f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
        return r.stdout

    return run
