"""Fallback `given`/`settings`/`st` so property tests *skip* when hypothesis
is absent (see requirements.txt) instead of killing collection for the whole
module.  Only the hypothesis-decorated tests degrade; every plain test in the
importing module still runs.
"""
import pytest


def settings(*_args, **_kwargs):
    def deco(fn):
        return fn
    return deco


def given(*_args, **_kwargs):
    def deco(fn):
        # Varargs-only stub: pytest ignores *args for fixture resolution, so
        # neither the hypothesis parameters (h=..., w=...) nor `self` are
        # treated as unresolvable fixtures, for methods and plain functions
        # alike.
        def stub(*_a):
            pytest.skip("hypothesis not installed (see requirements.txt)")
        stub.__name__ = fn.__name__
        stub.__doc__ = fn.__doc__
        return stub
    return deco


class _Strategies:
    """st.integers(...) etc. — arguments are never exercised by the stub."""

    def __getattr__(self, name):
        return lambda *a, **k: None


st = _Strategies()
