"""The assigned architecture configs must match the assignment sheet exactly."""
import pytest

from repro.configs import JACOBI_CONFIGS, get_config, list_archs

# (arch, layers, d_model, heads, kv, d_ff-or-expert, vocab)
SHEET = {
    "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
    "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
    "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
    "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
    "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
    "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
    "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
    "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
    "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
    "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
}


@pytest.mark.parametrize("arch", list_archs())
def test_exact_assignment_numbers(arch):
    cfg = get_config(arch)
    L, D, H, KV, FF, V = SHEET[arch]
    assert cfg.n_layers == L
    assert cfg.d_model == D
    assert cfg.n_heads == H
    assert cfg.n_kv_heads == KV
    assert cfg.vocab_size == V
    if cfg.family == "moe":
        assert cfg.d_ff_expert == FF
    elif cfg.family != "ssm":
        assert cfg.d_ff == FF


def test_moe_details():
    m = get_config("moonshot-v1-16b-a3b")
    assert (m.n_experts, m.top_k) == (64, 6)
    q = get_config("qwen3-moe-30b-a3b")
    assert (q.n_experts, q.top_k) == (128, 8)


def test_ssm_details():
    assert get_config("mamba2-370m").ssm_state == 128
    assert get_config("zamba2-1.2b").ssm_state == 64
    assert get_config("mamba2-370m").n_ssm_heads == 32   # 2048/64


def test_special_features():
    assert get_config("qwen3-0.6b").qk_norm
    assert get_config("qwen3-moe-30b-a3b").qk_norm
    assert get_config("nemotron-4-15b").activation == "relu2"
    assert get_config("qwen2-vl-2b").m_rope_sections is not None
    assert get_config("whisper-tiny").n_enc_layers == 4
    assert get_config("zamba2-1.2b").attn_every == 6


def test_every_arch_has_smoke_config():
    for arch in list_archs():
        smoke = get_config(arch, smoke=True)
        full = get_config(arch)
        assert smoke.family == full.family
        assert smoke.d_model <= 128


def test_jacobi_configs_match_paper():
    t1 = JACOBI_CONFIGS["table1-dense"]
    assert t1.grid == (64, 64) and t1.iterations == 7   # CS-1 dense limit
    assert JACOBI_CONFIGS["table1-conv"].iterations == 3500
    assert JACOBI_CONFIGS["table1-conv"].problem_elements == 2048 * 10**6
    assert JACOBI_CONFIGS["fig6-3d"].grid == (10, 64, 64)
    shapes = [JACOBI_CONFIGS[f"fig5-{s}"].grid
              for s in ("32x64", "64x64", "128x64", "128x128")]
    assert shapes == [(32, 64), (64, 64), (128, 64), (128, 128)]
