"""Distribution tests — these run in a subprocess (the ``run_with_devices``
fixture from tests/conftest.py) with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main test process
keeps its single-device view (per the assignment: only the dry-run forces
fake devices).
"""
import pytest

pytestmark = pytest.mark.slow


class TestHaloExchange:
    def test_distributed_jacobi_matches_reference(self, run_with_devices):
        # distributed stepping goes through the solve() entry point
        # (fixed-iteration mode); the raw runner is core.distributed.
        out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import laplace_jacobi, DirichletBC, solve
        from repro.core.reference import jacobi_reference

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        spec = laplace_jacobi(2)
        H, W, iters, bcv = 16, 8, 5, 1.5
        rng = np.random.default_rng(0)
        x0 = jnp.asarray(rng.standard_normal((2, H, W)), jnp.float32)
        out = solve(spec, x0, backend="halo", mesh=mesh, bc=bcv,
                    rtol=None, atol=None, max_iters=iters).x
        bc = DirichletBC(bcv)
        ref = jnp.stack([jacobi_reference(x0[i], spec, bc, iters)
                         for i in range(2)])
        err = float(jnp.abs(out - ref).max())
        assert err < 1e-5, err
        print("halo ok", err)
        """)
        assert "halo ok" in out

    def test_distributed_9point(self, run_with_devices):
        out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import box, DirichletBC, solve
        from repro.core.reference import jacobi_reference

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        spec = box(2)   # 9-point: corners must ride the two-phase exchange
        rng = np.random.default_rng(1)
        x0 = jnp.asarray(rng.standard_normal((1, 8, 16)), jnp.float32)
        out = solve(spec, x0, backend="halo", mesh=mesh, bc=0.5,
                    rtol=None, atol=None, max_iters=3).x
        ref = jnp.stack([jacobi_reference(x0[0], spec, DirichletBC(0.5), 3)])
        err = float(jnp.abs(out - ref).max())
        assert err < 1e-5, err
        print("box ok")
        """)
        assert "box ok" in out


class TestPipeline:
    def test_gpipe_matches_sequential_and_grads(self, run_with_devices):
        out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel.pipeline import gpipe, split_stages

        mesh = jax.make_mesh((4,), ("stage",))
        L, D = 8, 16
        rng = np.random.default_rng(0)
        W = jnp.asarray(rng.standard_normal((L, D, D)) * 0.2, jnp.float32)

        def stage_fn(ws, x):
            def body(x, w):
                return jnp.tanh(x @ w), None
            x, _ = jax.lax.scan(body, x, ws)
            return x

        x = jnp.asarray(rng.standard_normal((8, 5, D)), jnp.float32)
        pipe = gpipe(stage_fn, mesh, "stage", n_microbatches=4)
        with mesh:
            outp = pipe(split_stages(W, 4), x)
        ref = x
        for l in range(L):
            ref = jnp.tanh(ref @ W[l])
        assert float(jnp.abs(outp - ref).max()) < 1e-5

        def loss(W):
            return jnp.sum(pipe(split_stages(W, 4), x) ** 2)
        def loss_ref(W):
            r = x
            for l in range(L): r = jnp.tanh(r @ W[l])
            return jnp.sum(r ** 2)
        with mesh:
            g1 = jax.grad(loss)(W)
        g2 = jax.grad(loss_ref)(W)
        assert float(jnp.abs(g1 - g2).max()) < 1e-5
        print("pipe ok")
        """)
        assert "pipe ok" in out


class TestShardedTraining:
    def test_tp_training_matches_single_device(self, run_with_devices):
        out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models.model_zoo import build
        from repro.optim.adamw import AdamWConfig
        from repro.parallel.sharding import Sharder, tree_shardings
        from repro.train.train_step import (init_train_state, make_train_step,
                                            state_dims)

        cfg = get_config("qwen3-0.6b", smoke=True)
        api = build(cfg)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16))),
                 "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)))}

        # single-device reference
        state0 = init_train_state(api, jax.random.PRNGKey(0))
        step_ref = make_train_step(api, None, AdamWConfig())
        sref, mref = step_ref(state0, batch)

        # sharded over (2 data, 4 model)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        sharder = Sharder(mesh=mesh, profile="tp")
        step_sh = make_train_step(api, sharder, AdamWConfig())
        with mesh:
            ssh, msh = jax.jit(step_sh)(state0, batch)
        a = float(mref["loss"]); b = float(msh["loss"])
        assert abs(a - b) < 1e-3, (a, b)
        d = jax.tree.map(lambda x, y: float(jnp.abs(x - y).max()),
                         sref["params"], ssh["params"])
        worst = max(jax.tree.leaves(d))
        assert worst < 1e-4, worst
        print("tp ok", a, b, worst)
        """)
        assert "tp ok" in out

    def test_sp_profile_matches_single_device(self, run_with_devices):
        out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models.model_zoo import build
        from repro.optim.adamw import AdamWConfig
        from repro.parallel.sharding import Sharder
        from repro.train.train_step import init_train_state, make_train_step

        cfg = get_config("phi3-medium-14b", smoke=True)   # sp profile
        api = build(cfg)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16))),
                 "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)))}
        state0 = init_train_state(api, jax.random.PRNGKey(0))
        _, mref = make_train_step(api, None, AdamWConfig())(state0, batch)

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        sharder = Sharder(mesh=mesh, profile="sp")
        with mesh:
            _, msh = jax.jit(make_train_step(api, sharder, AdamWConfig()))(state0, batch)
        a, b = float(mref["loss"]), float(msh["loss"])
        assert abs(a - b) < 1e-3, (a, b)
        print("sp ok", a, b)
        """)
        assert "sp ok" in out

    def test_decode_with_sharded_cache(self, run_with_devices):
        out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models.model_zoo import build
        from repro.parallel.sharding import Sharder

        cfg = get_config("glm4-9b", smoke=True)
        api = build(cfg)
        params = api.init(jax.random.PRNGKey(0), jnp.float32)
        rng = np.random.default_rng(0)
        B, S = 4, 12
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))}

        # unsharded reference
        _, cache = api.prefill(params, batch, max_len=16)
        tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (B,)))
        ref_logits, _ = api.decode_step(params, tok, cache, S)

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        sharder = Sharder(mesh=mesh, profile="tp")
        with mesh:
            _, cache_s = jax.jit(lambda p, b: api.prefill(p, b, 16,
                                 sharder=sharder))(params, batch)
            logits_s, _ = jax.jit(lambda p, t, c: api.decode_step(
                p, t, c, S, sharder=sharder))(params, tok, cache_s)
        err = float(jnp.abs(ref_logits - logits_s).max())
        assert err < 2e-2, err
        print("decode ok", err)
        """)
        assert "decode ok" in out
