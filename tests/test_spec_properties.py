"""Property-based tests for StencilSpec invariants.

Two layers: deterministic sweeps over seeded random specs (always run, no
third-party deps) and hypothesis-driven versions of the same properties when
hypothesis is installed (see requirements.txt).
"""
import numpy as np
import pytest

from repro.core import (
    StencilSpec,
    WeightField,
    box,
    causal_conv1d_spec,
    heterogeneous_jacobi,
    laplace_jacobi,
    star,
    variable_coefficient,
)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    from _hypothesis_stub import given, settings, st
    HAVE_HYPOTHESIS = False


def random_spec(seed: int) -> StencilSpec:
    """A seeded random spec: ndim 1-3, radius <= 2, 1-9 distinct taps."""
    rng = np.random.default_rng(seed)
    ndim = int(rng.integers(1, 4))
    n_taps = int(rng.integers(1, min(10, 5 ** ndim + 1)))
    taps = {}
    while len(taps) < n_taps:
        off = tuple(int(o) for o in rng.integers(-2, 3, size=ndim))
        taps[off] = float(np.round(rng.standard_normal(), 3)) or 0.125
    return StencilSpec(taps=taps, name=f"rand{seed}")


def check_roundtrip(spec: StencilSpec):
    """to_kernel() must hold exactly the taps, each at its offset slot."""
    ker = spec.to_kernel()
    lo = [min(off[d] for off, _ in spec.taps) for d in range(spec.ndim)]
    reconstructed = {}
    for idx in np.ndindex(*ker.shape):
        if ker[idx] != 0.0:
            off = tuple(i + l for i, l in zip(idx, lo))
            reconstructed[off] = float(ker[idx])
    expected = {off: w for off, w in spec.taps if w != 0.0}
    assert reconstructed == pytest.approx(expected)


def check_radius_footprint(spec: StencilSpec):
    """radius is the max Chebyshev reach; footprint the tap bounding box."""
    offs = np.array([off for off, _ in spec.taps])
    assert spec.radius == int(np.abs(offs).max())
    expect_fp = tuple(int(offs[:, d].max() - offs[:, d].min() + 1)
                      for d in range(spec.ndim))
    assert spec.footprint == expect_fp
    assert all(f <= 2 * spec.radius + 1 for f in spec.footprint)
    assert int(np.prod(spec.footprint)) >= len(spec.taps)


def check_canonicalization(spec: StencilSpec):
    """Tap order must not matter: same spec, same hash, dict-key safe."""
    shuffled = list(spec.taps)[::-1]
    again = StencilSpec(taps=tuple(shuffled), name=spec.name)
    assert again == spec
    assert hash(again) == hash(spec)
    assert len({spec: 1, again: 2}) == 1
    from_mapping = StencilSpec(taps=dict(spec.taps), name=spec.name)
    assert from_mapping == spec


def check_flop_counts(spec: StencilSpec):
    n = len(spec.taps)
    assert spec.useful_flops_per_point == 2 * n - 1
    w = int(np.prod(spec.footprint))
    assert spec.delivered_flops_per_point_conv() == 2 * w - 1
    assert spec.delivered_flops_per_point_conv() >= spec.useful_flops_per_point


class TestDeterministicSweep:
    SEEDS = list(range(40))

    @pytest.mark.parametrize("seed", SEEDS)
    def test_kernel_roundtrip(self, seed):
        check_roundtrip(random_spec(seed))

    @pytest.mark.parametrize("seed", SEEDS)
    def test_radius_footprint_agree(self, seed):
        check_radius_footprint(random_spec(seed))

    @pytest.mark.parametrize("seed", SEEDS)
    def test_canonicalization_order_insensitive(self, seed):
        check_canonicalization(random_spec(seed))

    @pytest.mark.parametrize("seed", SEEDS)
    def test_flop_accounting(self, seed):
        check_flop_counts(random_spec(seed))


class TestPaperCounts:
    """The §4 numbers the FLOP model must reproduce exactly."""

    def test_2d_laplace_useful_is_7(self):
        assert laplace_jacobi(2).useful_flops_per_point == 7

    def test_2d_laplace_conv_delivered_is_17(self):
        assert laplace_jacobi(2).delivered_flops_per_point_conv() == 17

    def test_2d_laplace_dense_delivered_is_8191(self):
        assert laplace_jacobi(2).delivered_flops_per_point_dense(4096) == 8191

    def test_named_factories_roundtrip(self):
        for spec in (laplace_jacobi(1), laplace_jacobi(2), laplace_jacobi(3),
                     star(2, [0.1, 0.05], center=0.4), box(2), box(3),
                     causal_conv1d_spec([0.1, 0.2, 0.3, 0.4])):
            check_roundtrip(spec)
            check_radius_footprint(spec)
            check_canonicalization(spec)
            check_flop_counts(spec)

    def test_inconsistent_ranks_rejected(self):
        with pytest.raises(ValueError, match="inconsistent"):
            StencilSpec(taps={(1,): 0.5, (0, 1): 0.5})


class TestVariableCoefficientValidation:
    """Hardened __post_init__ / to_kernel: malformed weight fields must be
    rejected with clear errors, well-formed ones canonicalize cleanly."""

    FIELD = np.full((5, 7), 0.25, np.float32)

    def test_empty_taps_rejected(self):
        with pytest.raises(ValueError, match="at least one tap"):
            StencilSpec(taps={})

    def test_wrong_field_rank_rejected(self):
        with pytest.raises(ValueError, match="rank"):
            StencilSpec(taps={(0, 1): np.zeros((5,), np.float32) + 0.25,
                              (0, -1): 0.25})

    def test_mismatched_field_shapes_rejected(self):
        with pytest.raises(ValueError, match="disagree"):
            StencilSpec(taps={(0, 1): np.full((5, 7), 0.25),
                              (0, -1): np.full((6, 7), 0.25)})

    def test_scalar_weight_field_rejected(self):
        with pytest.raises(ValueError, match="not a scalar"):
            WeightField(np.float32(0.25))

    def test_non_numeric_weight_rejected(self):
        with pytest.raises(ValueError, match="malformed weight"):
            StencilSpec(taps={(0, 1): "fast"})

    def test_to_kernel_rejects_variable_spec(self):
        spec = StencilSpec(taps={(0, 1): self.FIELD, (0, -1): 0.25})
        with pytest.raises(ValueError, match="no single .*kernel"):
            spec.to_kernel()

    def test_array_weights_canonicalize_to_weight_fields(self):
        spec = StencilSpec(taps={(0, 1): self.FIELD, (0, -1): 0.25})
        kinds = {off: type(w) for off, w in spec.taps}
        assert kinds[(0, 1)] is WeightField
        assert kinds[(0, -1)] is float
        assert spec.is_variable
        assert spec.num_variable_taps == 1
        assert spec.weights_shape == (5, 7)

    def test_weight_field_is_immutable_and_hashable(self):
        wf = WeightField(self.FIELD)
        with pytest.raises(AttributeError):
            wf.array = np.zeros((2, 2))
        with pytest.raises(ValueError):
            wf.array[0, 0] = 1.0  # read-only buffer
        same = WeightField(self.FIELD.copy())
        assert wf == same and hash(wf) == hash(same)
        spec_a = StencilSpec(taps={(0, 1): wf, (0, -1): 0.25})
        spec_b = StencilSpec(taps={(0, 1): same, (0, -1): 0.25})
        assert spec_a == spec_b and len({spec_a: 1, spec_b: 2}) == 1

    def test_variable_coefficient_factory(self):
        spec = variable_coefficient(laplace_jacobi(2), {(0, 1): self.FIELD})
        assert spec.is_variable and spec.num_variable_taps == 1
        assert len(spec.taps) == 4

    def test_heterogeneous_jacobi_reduces_to_laplace(self):
        # Constant kappa: every tap field equals the laplace_jacobi weight.
        spec = heterogeneous_jacobi(np.full((6, 8), 3.0))
        assert spec.num_variable_taps == 4
        for _, w in spec.taps:
            np.testing.assert_allclose(w.array, 0.25, atol=1e-6)

    def test_heterogeneous_jacobi_rejects_bad_kappa(self):
        with pytest.raises(ValueError, match="positive"):
            heterogeneous_jacobi(np.zeros((4, 4)))
        with pytest.raises(ValueError, match="per-cell"):
            heterogeneous_jacobi(2.0)

    def test_field_shape_vs_grid_checked_at_apply(self):
        import jax.numpy as jnp
        from repro.core import stencil_apply
        spec = StencilSpec(taps={(0, 1): self.FIELD, (0, -1): 0.25})
        with pytest.raises(ValueError, match="weight fields"):
            stencil_apply(spec, jnp.zeros((8, 8), jnp.float32),
                          backend="reference", bc=0.0)


class TestWeightFieldPytree:
    """WeightField as a registered pytree: the property that lets fields
    live inside parameter trees and trace through jit/grad (ISSUE 9)."""

    FIELD = np.arange(15, dtype=np.float32).reshape(3, 5) + 1.0

    def test_flatten_unflatten_round_trips(self):
        import jax
        wf = WeightField(self.FIELD)
        leaves, treedef = jax.tree.flatten(wf)
        assert len(leaves) == 1
        back = jax.tree.unflatten(treedef, leaves)
        assert isinstance(back, WeightField)
        np.testing.assert_array_equal(back.array, wf.array)
        assert back == wf and hash(back) == hash(wf)

    def test_traced_field_refuses_hash_and_array(self):
        import jax
        import jax.numpy as jnp

        seen = {}

        @jax.jit
        def f(wf):
            with pytest.raises(TypeError, match="not hashable"):
                hash(wf)
            with pytest.raises(TypeError, match="traced"):
                _ = wf.array
            seen["ok"] = True
            return wf.values * 2.0

        out = f(WeightField(self.FIELD))
        assert seen["ok"]
        np.testing.assert_array_equal(np.asarray(out), self.FIELD * 2.0)

    def test_grad_flows_through_weight_field_leaf(self):
        import jax
        import jax.numpy as jnp

        def loss(wf):
            return jnp.sum(wf.values ** 2)

        g = jax.grad(loss)(WeightField(self.FIELD))
        assert isinstance(g, WeightField)
        np.testing.assert_allclose(np.asarray(g.values), 2.0 * self.FIELD)

    def test_tree_map_preserves_wrapper(self):
        import jax
        tree = {"a": WeightField(self.FIELD), "b": np.float32(3.0)}
        doubled = jax.tree.map(lambda x: x * 2, tree)
        assert isinstance(doubled["a"], WeightField)
        np.testing.assert_array_equal(np.asarray(doubled["a"].values),
                                      self.FIELD * 2)


class TestHypothesisSweep:
    """Same invariants, hypothesis-driven (skips when not installed)."""

    @settings(max_examples=50, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_all_invariants(self, seed):
        spec = random_spec(seed)
        check_roundtrip(spec)
        check_radius_footprint(spec)
        check_canonicalization(spec)
        check_flop_counts(spec)
