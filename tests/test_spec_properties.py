"""Property-based tests for StencilSpec invariants.

Two layers: deterministic sweeps over seeded random specs (always run, no
third-party deps) and hypothesis-driven versions of the same properties when
hypothesis is installed (see requirements.txt).
"""
import numpy as np
import pytest

from repro.core import StencilSpec, box, causal_conv1d_spec, laplace_jacobi, star

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    from _hypothesis_stub import given, settings, st
    HAVE_HYPOTHESIS = False


def random_spec(seed: int) -> StencilSpec:
    """A seeded random spec: ndim 1-3, radius <= 2, 1-9 distinct taps."""
    rng = np.random.default_rng(seed)
    ndim = int(rng.integers(1, 4))
    n_taps = int(rng.integers(1, min(10, 5 ** ndim + 1)))
    taps = {}
    while len(taps) < n_taps:
        off = tuple(int(o) for o in rng.integers(-2, 3, size=ndim))
        taps[off] = float(np.round(rng.standard_normal(), 3)) or 0.125
    return StencilSpec(taps=taps, name=f"rand{seed}")


def check_roundtrip(spec: StencilSpec):
    """to_kernel() must hold exactly the taps, each at its offset slot."""
    ker = spec.to_kernel()
    lo = [min(off[d] for off, _ in spec.taps) for d in range(spec.ndim)]
    reconstructed = {}
    for idx in np.ndindex(*ker.shape):
        if ker[idx] != 0.0:
            off = tuple(i + l for i, l in zip(idx, lo))
            reconstructed[off] = float(ker[idx])
    expected = {off: w for off, w in spec.taps if w != 0.0}
    assert reconstructed == pytest.approx(expected)


def check_radius_footprint(spec: StencilSpec):
    """radius is the max Chebyshev reach; footprint the tap bounding box."""
    offs = np.array([off for off, _ in spec.taps])
    assert spec.radius == int(np.abs(offs).max())
    expect_fp = tuple(int(offs[:, d].max() - offs[:, d].min() + 1)
                      for d in range(spec.ndim))
    assert spec.footprint == expect_fp
    assert all(f <= 2 * spec.radius + 1 for f in spec.footprint)
    assert int(np.prod(spec.footprint)) >= len(spec.taps)


def check_canonicalization(spec: StencilSpec):
    """Tap order must not matter: same spec, same hash, dict-key safe."""
    shuffled = list(spec.taps)[::-1]
    again = StencilSpec(taps=tuple(shuffled), name=spec.name)
    assert again == spec
    assert hash(again) == hash(spec)
    assert len({spec: 1, again: 2}) == 1
    from_mapping = StencilSpec(taps=dict(spec.taps), name=spec.name)
    assert from_mapping == spec


def check_flop_counts(spec: StencilSpec):
    n = len(spec.taps)
    assert spec.useful_flops_per_point == 2 * n - 1
    w = int(np.prod(spec.footprint))
    assert spec.delivered_flops_per_point_conv() == 2 * w - 1
    assert spec.delivered_flops_per_point_conv() >= spec.useful_flops_per_point


class TestDeterministicSweep:
    SEEDS = list(range(40))

    @pytest.mark.parametrize("seed", SEEDS)
    def test_kernel_roundtrip(self, seed):
        check_roundtrip(random_spec(seed))

    @pytest.mark.parametrize("seed", SEEDS)
    def test_radius_footprint_agree(self, seed):
        check_radius_footprint(random_spec(seed))

    @pytest.mark.parametrize("seed", SEEDS)
    def test_canonicalization_order_insensitive(self, seed):
        check_canonicalization(random_spec(seed))

    @pytest.mark.parametrize("seed", SEEDS)
    def test_flop_accounting(self, seed):
        check_flop_counts(random_spec(seed))


class TestPaperCounts:
    """The §4 numbers the FLOP model must reproduce exactly."""

    def test_2d_laplace_useful_is_7(self):
        assert laplace_jacobi(2).useful_flops_per_point == 7

    def test_2d_laplace_conv_delivered_is_17(self):
        assert laplace_jacobi(2).delivered_flops_per_point_conv() == 17

    def test_2d_laplace_dense_delivered_is_8191(self):
        assert laplace_jacobi(2).delivered_flops_per_point_dense(4096) == 8191

    def test_named_factories_roundtrip(self):
        for spec in (laplace_jacobi(1), laplace_jacobi(2), laplace_jacobi(3),
                     star(2, [0.1, 0.05], center=0.4), box(2), box(3),
                     causal_conv1d_spec([0.1, 0.2, 0.3, 0.4])):
            check_roundtrip(spec)
            check_radius_footprint(spec)
            check_canonicalization(spec)
            check_flop_counts(spec)

    def test_inconsistent_ranks_rejected(self):
        with pytest.raises(ValueError, match="inconsistent"):
            StencilSpec(taps={(1,): 0.5, (0, 1): 0.5})


class TestHypothesisSweep:
    """Same invariants, hypothesis-driven (skips when not installed)."""

    @settings(max_examples=50, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_all_invariants(self, seed):
        spec = random_spec(seed)
        check_roundtrip(spec)
        check_radius_footprint(spec)
        check_canonicalization(spec)
        check_flop_counts(spec)
