"""Flash-attention kernel vs the XLA attention oracle (interpret mode),
shape/dtype/GQA sweeps + causal masking properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.models.attention import attention

RNG = np.random.default_rng(5)


def _mk(B, Sq, Skv, H, KV, hd, dtype=jnp.float32):
    q = jnp.asarray(RNG.standard_normal((B, Sq, H, hd)), dtype)
    k = jnp.asarray(RNG.standard_normal((B, Skv, KV, hd)), dtype)
    v = jnp.asarray(RNG.standard_normal((B, Skv, KV, hd)), dtype)
    return q, k, v


class TestFlashAttention:
    @pytest.mark.parametrize("B,S,H,KV,hd", [
        (1, 128, 2, 2, 32),    # MHA
        (2, 96, 4, 2, 16),     # GQA 2:1, ragged seq
        (1, 256, 8, 1, 32),    # MQA
    ])
    def test_causal_matches_oracle(self, B, S, H, KV, hd):
        q, k, v = _mk(B, S, S, H, KV, hd)
        out = flash_attention(q, k, v, causal=True, block_q=32, block_k=128)
        ref = attention(q, k, v, causal=True, q_chunk=S)
        np.testing.assert_allclose(out, ref, atol=2e-5)

    def test_non_causal(self):
        q, k, v = _mk(1, 64, 64, 2, 2, 16)
        out = flash_attention(q, k, v, causal=False, block_q=32, block_k=128)
        ref = attention(q, k, v, causal=False, q_chunk=64)
        np.testing.assert_allclose(out, ref, atol=2e-5)

    def test_cross_lengths(self):
        # decoder query over longer kv (prefix attention)
        q, k, v = _mk(1, 32, 160, 2, 2, 16)
        out = flash_attention(q, k, v, causal=True, kv_offset=128,
                              block_q=32, block_k=128)
        ref = attention(q, k, v, causal=True, q_chunk=32, kv_offset=-128)
        np.testing.assert_allclose(out, ref, atol=2e-5)

    def test_bf16(self):
        q, k, v = _mk(1, 128, 128, 2, 2, 32, jnp.bfloat16)
        out = flash_attention(q, k, v, causal=True, block_q=32, block_k=128)
        ref = attention(q, k, v, causal=True, q_chunk=128)
        np.testing.assert_allclose(out.astype(np.float32),
                                   ref.astype(np.float32), atol=3e-2)

    def test_block_size_invariance(self):
        q, k, v = _mk(1, 128, 128, 2, 2, 16)
        a = flash_attention(q, k, v, block_q=32, block_k=128)
        b = flash_attention(q, k, v, block_q=64, block_k=128)
        np.testing.assert_allclose(a, b, atol=2e-5)

    def test_causal_first_token_attends_self_only(self):
        q, k, v = _mk(1, 64, 64, 1, 1, 16)
        out = flash_attention(q, k, v, causal=True, block_q=32, block_k=128)
        np.testing.assert_allclose(out[0, 0, 0], v[0, 0, 0].astype(out.dtype),
                                   atol=1e-5)


class TestFlashBackward:
    """flash_attention_trainable (custom_vjp fwd+bwd kernels) vs oracle grads."""

    @pytest.mark.parametrize("B,S,H,KV,hd", [
        (1, 128, 2, 2, 32),   # MHA
        (2, 96, 4, 2, 16),    # GQA (dk/dv accumulate over the group dim)
        (1, 64, 4, 1, 16),    # MQA
    ])
    def test_grads_match_oracle(self, B, S, H, KV, hd):
        from repro.kernels.flash_attention_bwd import flash_attention_trainable
        q, k, v = _mk(B, S, S, H, KV, hd)

        def f(q, k, v):
            return jnp.sum(flash_attention_trainable(q, k, v, True, 32, 128, 0) ** 2)

        def g(q, k, v):
            return jnp.sum(attention(q, k, v, causal=True, q_chunk=S) ** 2)

        out = flash_attention_trainable(q, k, v, True, 32, 128, 0)
        ref = attention(q, k, v, causal=True, q_chunk=S)
        np.testing.assert_allclose(out, ref, atol=2e-5)
        d1 = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
        d2 = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(d1, d2):
            np.testing.assert_allclose(a, b, atol=5e-5)

    def test_non_causal_grads(self):
        from repro.kernels.flash_attention_bwd import flash_attention_trainable
        q, k, v = _mk(1, 64, 64, 2, 2, 16)
        d1 = jax.grad(lambda q: jnp.sum(
            flash_attention_trainable(q, k, v, False, 32, 128, 0) ** 2))(q)
        d2 = jax.grad(lambda q: jnp.sum(
            attention(q, k, v, causal=False, q_chunk=64) ** 2))(q)
        np.testing.assert_allclose(d1, d2, atol=5e-5)


def test_flash_impl_in_model_matches_xla():
    """cfg.attn_impl='flash' swaps the Pallas kernels into the transformer;
    forward and gradients must match the XLA attention path."""
    import dataclasses
    from repro.configs import get_config
    from repro.models.model_zoo import build
    from repro.train.train_step import loss_fn

    rng = np.random.default_rng(0)
    cfg = get_config("qwen3-0.6b", smoke=True)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32))),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)))}
    api_x = build(cfg)
    api_f = build(dataclasses.replace(cfg, attn_impl="flash"))
    params = api_x.init(jax.random.PRNGKey(0), jnp.float32)
    hx, _ = api_x.forward(params, batch)
    hf, _ = api_f.forward(params, batch)
    np.testing.assert_allclose(hx, hf, atol=1e-4)
    gx = jax.grad(lambda p: loss_fn(api_x, p, batch, None)[0])(params)
    gf = jax.grad(lambda p: loss_fn(api_f, p, batch, None)[0])(params)
    for a, b in zip(jax.tree.leaves(gx), jax.tree.leaves(gf)):
        np.testing.assert_allclose(a, b, atol=5e-3)
