"""Halo-exchange edge semantics and boundary-mode equivalence, exercised
through the unified ``stencil_apply`` dispatcher.

Multi-device cases run in a subprocess (the ``run_with_devices`` fixture
from tests/conftest.py) with XLA_FLAGS=--xla_force_host_platform_device_count
so the main pytest process keeps its single-device view.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BoundaryMode,
    DirichletBC,
    jacobi_reference,
    laplace_jacobi,
    star,
    stencil_apply,
)

RNG = np.random.default_rng(3)


class TestBoundaryModeEquivalence:
    """MASK ≡ PAD ≡ MATRIX: three BC encodings, one operator (boundary.py)."""

    def test_all_modes_agree_on_same_grid(self):
        spec = laplace_jacobi(2)
        x = jnp.asarray(RNG.standard_normal((2, 16, 12)), jnp.float32)
        outs = {
            "conv+mask": stencil_apply(spec, x, backend="conv", bc=2.5,
                                       mode=BoundaryMode.MASK, iters=5),
            "conv+pad": stencil_apply(spec, x, backend="conv", bc=2.5,
                                      mode=BoundaryMode.PAD, iters=5),
            "dense+matrix": stencil_apply(spec, x, backend="dense", bc=2.5,
                                          mode=BoundaryMode.MATRIX, iters=5),
            "pallas+mask": stencil_apply(spec, x, backend="pallas", bc=2.5,
                                         mode=BoundaryMode.MASK, iters=5),
        }
        ref = jnp.stack([jacobi_reference(x[i], spec, DirichletBC(2.5), 5)
                         for i in range(2)])
        for name, out in outs.items():
            np.testing.assert_allclose(out, ref, atol=1e-5, err_msg=name)

    def test_modes_agree_for_negative_bc(self):
        spec = laplace_jacobi(2)
        x = jnp.asarray(RNG.standard_normal((1, 10, 14)), jnp.float32)
        a = stencil_apply(spec, x, backend="conv", bc=-3.0,
                          mode=BoundaryMode.MASK, iters=4)
        b = stencil_apply(spec, x, backend="conv", bc=-3.0,
                          mode=BoundaryMode.PAD, iters=4)
        c = stencil_apply(spec, x, backend="dense", bc=-3.0,
                          mode=BoundaryMode.MATRIX, iters=4)
        np.testing.assert_allclose(a, b, atol=1e-5)
        np.testing.assert_allclose(b, c, atol=1e-5)


class TestHaloSingleDevice:
    """The halo backend degenerates gracefully to a 1x1 mesh in-process."""

    def test_halo_matches_oracle_single_device(self):
        spec = laplace_jacobi(2)
        x = jnp.asarray(RNG.standard_normal((2, 16, 8)), jnp.float32)
        out = stencil_apply(spec, x, backend="halo", bc=1.5, iters=4)
        ref = jnp.stack([jacobi_reference(x[i], spec, DirichletBC(1.5), 4)
                         for i in range(2)])
        np.testing.assert_allclose(out, ref, atol=1e-5)

    def test_halo_radius2_single_device(self):
        spec = star(2, [0.1, 0.05], center=0.3)
        x = jnp.asarray(RNG.standard_normal((1, 12, 16)), jnp.float32)
        out = stencil_apply(spec, x, backend="halo", bc=0.5, iters=3)
        ref = jnp.stack([jacobi_reference(x[0], spec, DirichletBC(0.5), 3)])
        np.testing.assert_allclose(out, ref, atol=1e-5)


@pytest.mark.slow
class TestHaloMultiDevice:
    def test_edge_permutes_deliver_zeros(self, run_with_devices):
        # Non-wrapping ppermute: the halo a mesh-edge device receives from
        # "outside" the mesh must be zeros (the oracle's zero-pad semantics).
        out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.parallel.halo import exchange_halo_2d, shard_map_compat

        mesh = jax.make_mesh((2, 4), ("row", "col"))
        H, W, r = 8, 16, 2
        x = jnp.asarray(np.arange(1, H * W + 1, dtype=np.float32).reshape(H, W))

        def gather_padded(xl):
            xp = exchange_halo_2d(xl, "row", "col", 2, 4, r)
            # re-assemble the halo-augmented tiles for inspection
            return xp[None]

        fn = shard_map_compat(gather_padded, mesh, (P("row", "col"),),
                              P(None, "row", "col"))
        tiles = np.asarray(fn(x))  # (1, 2*(4+2r), 4*(4+2r))
        th, tw = H // 2 + 2 * r, W // 4 + 2 * r
        tiles = tiles[0].reshape(2, th, 4, tw).transpose(0, 2, 1, 3)

        # Global top edge: row-0 tiles' low halo rows are all zero.
        assert np.all(tiles[0, :, :r, :] == 0.0)
        # Global bottom edge: row-1 tiles' high halo rows are all zero.
        assert np.all(tiles[1, :, -r:, :] == 0.0)
        # Global left/right edges likewise.
        assert np.all(tiles[:, 0, :, :r] == 0.0)
        assert np.all(tiles[:, 3, :, -r:] == 0.0)
        # Interior seams carry the true neighbour values, not zeros: tile
        # (0,1)'s left halo is tile (0,0)'s rightmost r columns.
        xnp = np.asarray(x)
        np.testing.assert_array_equal(tiles[0, 1, r:-r, :r],
                                      xnp[0:4, 4 - r:4])
        print("edge zeros ok")
        """)
        assert "edge zeros ok" in out

    def test_stencil_apply_halo_on_device_mesh(self, run_with_devices):
        out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import DirichletBC, jacobi_reference, laplace_jacobi
        from repro.core.plan import stencil_apply

        mesh = jax.make_mesh((4, 2), ("row", "col"))
        spec = laplace_jacobi(2)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((2, 16, 8)), jnp.float32)
        out = stencil_apply(spec, x, backend="halo", bc=1.5, iters=5,
                            mesh=mesh)
        ref = jnp.stack([jacobi_reference(x[i], spec, DirichletBC(1.5), 5)
                         for i in range(2)])
        err = float(jnp.abs(out - ref).max())
        assert err < 1e-5, err
        print("halo mesh ok", err)
        """)
        assert "halo mesh ok" in out

    def test_halo_support_rejects_untileable_grid(self, run_with_devices):
        out = run_with_devices("""
        import jax
        from repro.core import backend_support, laplace_jacobi

        mesh = jax.make_mesh((4, 2), ("row", "col"))
        sup = backend_support("halo", laplace_jacobi(2), grid_shape=(15, 8),
                              mesh=mesh)
        assert not sup.ok and "tile" in sup.reason, sup
        print("reject ok")
        """)
        assert "reject ok" in out
