"""Cross-backend conformance matrix for the unified stencil dispatcher.

Walks every cell of (stencil family × ndim) × backend × boundary mode ×
dtype and asserts the backend matches the NumPy/jnp oracle within dtype
tolerance — or is *explicitly* skipped with the reason string that
``backend_support`` reports.  This is the executable form of the paper's
central claim: every tensor-program encoding of a stencil computes the same
operator.

Pallas cells run in interpret mode on CPU (the kernels auto-select it), so
the whole matrix passes on CPU CI.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BACKENDS,
    BoundaryMode,
    DirichletBC,
    backend_support,
    box,
    causal_conv1d_spec,
    choose_backend,
    heterogeneous_jacobi,
    jacobi_reference,
    laplace_jacobi,
    star,
    stencil_apply,
    variable_coefficient,
)

RNG = np.random.default_rng(20260802)

ITERS = 2
BC_VALUE = 1.5

# Small odd-shaped grids: exercise block padding without slowing interpret mode.
GRIDS = {1: (33,), 2: (12, 17), 3: (6, 10, 12)}


def _kappa(ndim):
    """A smooth positive conductivity field matching the test grid."""
    return 1.0 + 9.0 * RNG.random(GRIDS[ndim]).astype(np.float32)


SPECS = {
    "laplace/1d": laplace_jacobi(1),
    "laplace/2d": laplace_jacobi(2),
    "laplace/3d": laplace_jacobi(3),
    "star_r2/1d": star(1, [0.15, 0.05], center=0.2),
    "star_r2/2d": star(2, [0.15, 0.05], center=0.2),
    "star_r2/3d": star(3, [0.15, 0.05], center=0.2),
    "box/1d": box(1),
    "box/2d": box(2),
    "box/3d": box(3),
    "causal_conv1d/1d": causal_conv1d_spec([0.1, 0.2, 0.3, 0.4]),
    # Variable-coefficient cells: every tap carries a per-cell weight field
    # (heterogeneous diffusion), or a mix of scalar and per-cell taps.
    "varcoef/1d": heterogeneous_jacobi(_kappa(1)),
    "varcoef/2d": heterogeneous_jacobi(_kappa(2)),
    "varcoef/3d": heterogeneous_jacobi(_kappa(3)),
    "varcoef_mixed/2d": variable_coefficient(
        laplace_jacobi(2),
        {(0, 1): 0.25 + 0.1 * RNG.random(GRIDS[2]).astype(np.float32)},
        name="varmix2d"),
}

MODES = (BoundaryMode.MASK, BoundaryMode.PAD, BoundaryMode.MATRIX)
DTYPES = {"f32": (jnp.float32, 2e-5), "bf16": (jnp.bfloat16, 6e-2)}


def _oracle(spec, x):
    bc = DirichletBC(BC_VALUE)
    return jnp.stack([jacobi_reference(x[i].astype(jnp.float32), spec, bc,
                                       ITERS) for i in range(x.shape[0])])


@pytest.mark.slow
@pytest.mark.parametrize("dtype_name", list(DTYPES))
@pytest.mark.parametrize("mode", MODES, ids=lambda m: m.value)
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("family", list(SPECS))
def test_matrix_cell(family, backend, mode, dtype_name):
    spec = SPECS[family]
    grid = GRIDS[spec.ndim]
    dtype, atol = DTYPES[dtype_name]

    sup = backend_support(backend, spec, grid_shape=grid, mode=mode,
                          bc=BC_VALUE)
    if not sup:
        pytest.skip(f"{backend}/{family}/{mode.value}: {sup.reason}")

    x = jnp.asarray(RNG.standard_normal((2, *grid)), dtype)
    out = stencil_apply(spec, x, backend=backend, bc=BC_VALUE, mode=mode,
                        iters=ITERS)
    assert out.dtype == dtype
    ref = _oracle(spec, x)
    np.testing.assert_allclose(out.astype(jnp.float32), ref, atol=atol,
                               err_msg=f"{backend} diverges from oracle on "
                                       f"{family} {mode.value} {dtype_name}")


class TestRawZeroPad:
    """bc=None cells: raw repeated application with implicit zero padding."""

    @pytest.mark.parametrize("backend", ["reference", "pallas", "pallas_fused"])
    @pytest.mark.parametrize("ndim", [2, 3])
    def test_raw_matches_oracle(self, backend, ndim):
        spec = laplace_jacobi(ndim)
        sup = backend_support(backend, spec, grid_shape=GRIDS[ndim], bc=None)
        if not sup:
            pytest.skip(sup.reason)
        x = jnp.asarray(RNG.standard_normal((1, *GRIDS[ndim])), jnp.float32)
        out = stencil_apply(spec, x, backend=backend, bc=None, iters=ITERS)
        ref = stencil_apply(spec, x, backend="reference", bc=None, iters=ITERS)
        np.testing.assert_allclose(out, ref, atol=1e-5)


class TestAutoBackend:
    """Acceptance: backend="auto" is oracle-identical on the paper benchmarks."""

    def test_auto_2d_paper_benchmark(self):
        # Paper Table 1 shape: X=Y=64, Dirichlet BC = 1.0.
        spec = laplace_jacobi(2)
        x = jnp.asarray(RNG.standard_normal((2, 64, 64)), jnp.float32)
        out = stencil_apply(spec, x, backend="auto", bc=1.0, iters=10)
        ref = jnp.stack([jacobi_reference(x[i], spec, DirichletBC(1.0), 10)
                         for i in range(2)])
        np.testing.assert_allclose(out, ref, atol=1e-5)

    def test_auto_3d_paper_benchmark(self):
        # Paper Fig 6 shape: (Z, X, Y) = (10, 64, 64).
        spec = laplace_jacobi(3)
        x = jnp.asarray(RNG.standard_normal((1, 10, 64, 64)), jnp.float32)
        out = stencil_apply(spec, x, backend="auto", bc=1.0, iters=4)
        ref = jnp.stack([jacobi_reference(x[0], spec, DirichletBC(1.0), 4)])
        np.testing.assert_allclose(out, ref, atol=1e-5)

    def test_auto_choice_is_supported_and_deterministic(self):
        spec = laplace_jacobi(2)
        a, costs = choose_backend(spec, (64, 64), iters=20)
        b, _ = choose_backend(spec, (64, 64), iters=20)
        assert a == b
        assert backend_support(a, spec, grid_shape=(64, 64)).ok
        assert costs[a] == min(costs.values())

    def test_auto_cost_model_device_kinds(self):
        # CPU must never pick interpret-mode Pallas; TPU should exploit
        # temporal fusion for iteration-heavy 2D runs (DESIGN §2).
        spec = laplace_jacobi(2)
        cpu_choice, _ = choose_backend(spec, (64, 64), iters=20,
                                       device_kind="cpu")
        assert cpu_choice not in ("pallas", "pallas_fused")
        tpu_choice, _ = choose_backend(spec, (64, 64), iters=20,
                                       device_kind="tpu")
        assert tpu_choice == "pallas_fused"

    def test_auto_1d_falls_back_to_a_legal_backend(self):
        spec = causal_conv1d_spec([0.1, 0.2, 0.3, 0.4])
        name, _ = choose_backend(spec, (64,), iters=4)
        assert backend_support(name, spec, grid_shape=(64,)).ok


class TestDispatcherContract:
    def test_unbatched_input_round_trips(self):
        spec = laplace_jacobi(2)
        x = jnp.asarray(RNG.standard_normal((12, 17)), jnp.float32)
        out = stencil_apply(spec, x, backend="conv", bc=1.0, iters=2)
        assert out.shape == x.shape
        ref = jacobi_reference(x, spec, DirichletBC(1.0), 2)
        np.testing.assert_allclose(out, ref, atol=1e-5)

    def test_unknown_backend_rejected(self):
        spec = laplace_jacobi(2)
        x = jnp.zeros((1, 8, 8), jnp.float32)
        with pytest.raises(ValueError, match="unknown backend"):
            stencil_apply(spec, x, backend="tensorflow")

    def test_unsupported_cell_raises_with_reason(self):
        spec = star(2, [0.1, 0.05])  # radius 2
        x = jnp.zeros((1, 12, 12), jnp.float32)
        with pytest.raises(ValueError, match="radius-1"):
            stencil_apply(spec, x, backend="conv", bc=1.0,
                          mode=BoundaryMode.PAD)

    def test_grid_rank_mismatch_rejected(self):
        with pytest.raises(ValueError, match="incompatible"):
            stencil_apply(laplace_jacobi(3), jnp.zeros((4, 4), jnp.float32))

    def test_every_skip_reason_is_nonempty(self):
        # The conformance matrix depends on reasons being real sentences.
        for name, spec in SPECS.items():
            for b in BACKENDS:
                for m in MODES:
                    sup = backend_support(b, spec, grid_shape=GRIDS[spec.ndim],
                                          mode=m, bc=BC_VALUE)
                    if not sup:
                        assert len(sup.reason) > 10, (name, b, m)


class TestVariableCoefficientSupport:
    """The variable-coefficient cells that cannot run must say why."""

    def test_pallas_fused_variable_coefficients_are_live(self):
        # Earlier the fused kernel rejected var specs (the fields would have
        # needed halo replication); they now stream as a halo-replicated
        # operand sliced per in-kernel iteration, so the cell is live — and
        # must match the oracle at a fuse depth > 1.
        spec = SPECS["varcoef/2d"]
        sup = backend_support("pallas_fused", spec, grid_shape=GRIDS[2],
                              bc=BC_VALUE)
        assert sup.ok, sup.reason
        x = jnp.asarray(RNG.standard_normal((2, *GRIDS[2])), jnp.float32)
        out = stencil_apply(spec, x, backend="pallas_fused", bc=BC_VALUE,
                            iters=ITERS, fuse=ITERS)
        np.testing.assert_allclose(out, _oracle(spec, x), atol=2e-5)

    def test_halo_variable_coefficients_are_live(self):
        # PR 3 left this cell as a reasoned skip; the fields now shard with
        # the grid and are halo-exchanged once per chunk, so the cell is
        # live — and must match the oracle (1x1 mesh runs in-process).
        spec = SPECS["varcoef/2d"]
        sup = backend_support("halo", spec, grid_shape=GRIDS[2], bc=BC_VALUE)
        assert sup.ok, sup.reason
        x = jnp.asarray(RNG.standard_normal((2, *GRIDS[2])), jnp.float32)
        out = stencil_apply(spec, x, backend="halo", bc=BC_VALUE, iters=ITERS)
        np.testing.assert_allclose(out, _oracle(spec, x), atol=2e-5)

    def test_conv_3d_channels_reports_reasoned_skip(self):
        spec = SPECS["varcoef/3d"]
        sup = backend_support("conv", spec, grid_shape=GRIDS[3], bc=BC_VALUE)
        assert not sup and "channels-trick" in sup.reason

    def test_mismatched_field_shape_rejected_everywhere(self):
        spec = SPECS["varcoef/2d"]
        for b in BACKENDS:
            sup = backend_support(b, spec, grid_shape=(8, 8), bc=BC_VALUE)
            assert not sup and "weight fields" in sup.reason, b

    def test_supported_variable_cells_cover_all_dims(self):
        # Every varcoef family must have at least one real (non-oracle)
        # backend per ndim, or the matrix would silently test nothing.
        for name in ("varcoef/1d", "varcoef/2d", "varcoef/3d",
                     "varcoef_mixed/2d"):
            spec = SPECS[name]
            legal = [b for b in BACKENDS if b != "reference" and any(
                backend_support(b, spec, grid_shape=GRIDS[spec.ndim],
                                mode=m, bc=BC_VALUE) for m in MODES)]
            assert legal, name
