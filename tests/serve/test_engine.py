"""Serving-engine tests (serve/engine.py) — coalescing, admission, fan-out.

The contract under test: a request submitted through the engine resolves to
*exactly* the result a standalone cached solve would produce (coalescing is
an execution detail, not a semantic one); compatible concurrent requests
share one batched dispatch; incompatible ones split into groups; overload is
rejected fast with a reason; multigrid requests route through the same cache.

All engine interaction goes through ``asyncio.run`` so the tests carry no
event-loop plugin dependency.
"""
import asyncio

import numpy as np
import pytest

from repro.core import PlanCache, laplace_jacobi
from repro.serve import EngineStats, RejectedError, ServingEngine

GRID = (12, 12)
BC = 0.5
KW = dict(bc=BC, rtol=1e-4, check_every=10, max_iters=2000)


def _x0(seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(GRID).astype(np.float32)
    shell = np.ones(GRID, np.float32)
    shell[tuple(slice(1, -1) for _ in GRID)] = 0.0
    return x * (1.0 - shell) + BC * shell


def _cache():
    return PlanCache(probe=False)


def test_round_trip_matches_direct_solve():
    cache = _cache()
    x0 = _x0()

    async def main():
        async with ServingEngine(cache, max_wait=0.0) as eng:
            return await eng.submit(laplace_jacobi(2), x0, **KW)

    res = asyncio.run(main())
    want = cache.solve(laplace_jacobi(2), x0, **KW)
    assert res.converged
    assert np.array_equal(np.asarray(res.x), np.asarray(want.x))
    assert res.iterations == want.iterations
    assert res.x.shape == GRID


def test_coalescing_is_exact_and_batches_once():
    cache = _cache()
    problems = [_x0(seed=s) for s in range(5)]

    async def main():
        eng = ServingEngine(cache, max_batch=8, max_wait=0.1)
        async with eng:
            results = await asyncio.gather(
                *(eng.submit(laplace_jacobi(2), x0, **KW)
                  for x0 in problems))
        return eng, results

    eng, results = asyncio.run(main())
    assert eng.stats.batches == 1
    assert eng.stats.coalesced == 5
    assert eng.stats.mean_batch == 5.0
    for x0, res in zip(problems, results):
        want = cache.solve(laplace_jacobi(2), x0, **KW)
        assert np.array_equal(np.asarray(res.x), np.asarray(want.x))
        assert res.iterations == want.iterations
        assert res.converged == want.converged
        # the batch runs until its slowest member converges, so a request's
        # history column may extend past its own convergence point (frozen
        # residuals) — never the other way around
        assert (res.residual_history.shape[0]
                >= want.residual_history.shape[0])


def test_per_request_sources_coalesce():
    cache = _cache()
    rng = np.random.default_rng(9)
    srcs = [None, (rng.standard_normal(GRID) * 1e-2).astype(np.float32)]

    async def main():
        async with ServingEngine(cache, max_batch=4, max_wait=0.1) as eng:
            return await asyncio.gather(
                *(eng.submit(laplace_jacobi(2), _x0(seed=i), source=s, **KW)
                  for i, s in enumerate(srcs)))

    results = asyncio.run(main())
    for i, (src, res) in enumerate(zip(srcs, results)):
        want = cache.solve(laplace_jacobi(2), _x0(seed=i), source=src, **KW)
        assert np.array_equal(np.asarray(res.x), np.asarray(want.x))


def test_incompatible_requests_split_groups():
    cache = _cache()

    async def main():
        eng = ServingEngine(cache, max_batch=8, max_wait=0.1)
        async with eng:
            results = await asyncio.gather(
                eng.submit(laplace_jacobi(2), _x0(0), **KW),
                eng.submit(laplace_jacobi(2), _x0(1), **dict(KW, rtol=1e-5)))
        return eng, results

    eng, results = asyncio.run(main())
    assert all(r.converged for r in results)
    assert eng.stats.batches == 2   # different convergence cfg -> two solves
    assert eng.stats.completed == 2


def test_backpressure_rejects_with_reason():
    cache = _cache()

    async def main():
        async with ServingEngine(cache, max_queue=1, max_wait=0.0) as eng:
            eng.pause()
            first = asyncio.ensure_future(
                eng.submit(laplace_jacobi(2), _x0(0), **KW))
            await asyncio.sleep(0.05)   # first is admitted and held
            with pytest.raises(RejectedError) as exc:
                await eng.submit(laplace_jacobi(2), _x0(1), **KW)
            eng.resume()
            res = await first
            return eng, res, exc.value

    eng, res, err = asyncio.run(main())
    assert res.converged
    assert "queue full" in err.reason and "max_queue=1" in err.reason
    assert eng.stats.rejected == 1 and eng.stats.accepted == 1


def test_submit_after_stop_rejects():
    cache = _cache()

    async def main():
        eng = ServingEngine(cache)
        await eng.start()
        await eng.stop()
        with pytest.raises(RejectedError):
            await eng.submit(laplace_jacobi(2), _x0(), **KW)

    asyncio.run(main())


def test_multigrid_routes_through_cache():
    cache = _cache()
    x0 = np.zeros((17, 17), np.float32)

    async def main():
        async with ServingEngine(cache, max_wait=0.0) as eng:
            # sequential: the second dispatch must hit the cached hierarchy
            r1 = await eng.submit(laplace_jacobi(2), x0, method="multigrid",
                                  bc=0.0, rtol=1e-4)
            r2 = await eng.submit(laplace_jacobi(2), x0 + 0.1,
                                  method="multigrid", bc=0.0, rtol=1e-4)
        return r1, r2

    r1, r2 = asyncio.run(main())
    assert r1.converged and r2.converged
    assert any(k[0] == "multigrid" for k in cache.keys())
    assert cache.stats.hits == 1 and cache.stats.misses == 1


def test_input_validation():
    async def main():
        async with ServingEngine(_cache()) as eng:
            with pytest.raises(ValueError, match="bare"):
                await eng.submit(laplace_jacobi(2),
                                 np.zeros((2, *GRID), np.float32), **KW)
            with pytest.raises(ValueError, match="method"):
                await eng.submit(laplace_jacobi(2), _x0(), method="sor",
                                 **KW)
            with pytest.raises(ValueError, match="scalar"):
                await eng.submit(laplace_jacobi(2), _x0(),
                                 bc=np.zeros(GRID))  # type: ignore[arg-type]

    asyncio.run(main())


def test_stats_as_dict_and_constructor_validation():
    d = EngineStats(accepted=3, completed=2, batches=1).as_dict()
    assert d["accepted"] == 3 and d["mean_batch"] == 2.0
    with pytest.raises(ValueError):
        ServingEngine(_cache(), max_batch=0)
    with pytest.raises(ValueError):
        ServingEngine(_cache(), max_queue=0)
