"""Plan-cache tests (core/plan_cache.py) — the serving tier's artifact store.

Four layers of pinning:

  * keying — shapes sharing a power-of-two bucket share one compiled entry
    (hit), different buckets and scalar-weight variations of one tap-offset
    family behave as documented, and non-bucketable requests (dense/MATRIX,
    bc=None, array BCs, oversized pad ratios) degrade to exact entries that
    still cache;
  * exactness — a pad-to-bucket solve reproduces the unpadded solve on the
    same backend bit-for-bit: field, per-instance iteration counts,
    convergence flags; covered for bare, batched, variable-coefficient and
    source-carrying requests;
  * lifecycle — LRU eviction order, corrupt-entry evict-and-rebuild-once,
    stats counters (hits/misses/evictions/rebuilds/compile-seconds);
  * concurrency — racing threads on one key build it exactly once.

Probing is disabled (``probe=False``) except where the probe itself is under
test: these tests pin cache mechanics, not backend choice, and the roofline
path keeps them fast.
"""
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DirichletBC,
    PlanCache,
    StencilSpec,
    default_plan_cache,
    heterogeneous_jacobi,
    laplace_jacobi,
    set_default_plan_cache,
    solve,
)
from repro.core.boundary import BoundaryMode

GRID = (12, 12)
KW = dict(bc=0.5, rtol=1e-4, atol=0.0, check_every=10, max_iters=2000)


def _x0(grid, batch=None, seed=0, bc=0.5):
    """Random interior, shell at the Dirichlet value."""
    rng = np.random.default_rng(seed)
    shape = grid if batch is None else (batch, *grid)
    x = rng.standard_normal(shape).astype(np.float32)
    shell = np.ones(grid, np.float32)
    shell[tuple(slice(1, -1) for _ in grid)] = 0.0
    return x * (1.0 - shell) + bc * shell


def _cache(**kw):
    kw.setdefault("probe", False)
    return PlanCache(**kw)


class TestKeying:
    def test_same_bucket_hits(self):
        cache = _cache()
        s1 = cache.solver(laplace_jacobi(2), (12, 12), **KW)
        s2 = cache.solver(laplace_jacobi(2), (14, 10), **KW)
        assert s1.padded and s2.padded
        assert s1.bucket == s2.bucket == (16, 16)
        assert len(cache) == 1
        assert cache.stats.misses == 1 and cache.stats.hits == 1

    def test_different_bucket_misses(self):
        cache = _cache()
        cache.solver(laplace_jacobi(2), (12, 12), **KW)
        s = cache.solver(laplace_jacobi(2), (20, 20), **KW)
        assert s.bucket == (32, 32)
        assert len(cache) == 2 and cache.stats.misses == 2

    def test_scalar_weight_family_shares_entry(self):
        # Same tap offsets, different scalar weights -> one compiled loop
        # (weights stream through the fields operand).
        cache = _cache()
        cache.solver(laplace_jacobi(2), GRID, **KW)
        other = StencilSpec(
            taps={off: 0.2 for off, _ in laplace_jacobi(2).taps},
            name="fat_laplace")
        s = cache.solver(other, GRID, **KW)
        assert s.padded
        assert len(cache) == 1 and cache.stats.hits == 1

    def test_dirichlet_value_shares_entry(self):
        cache = _cache()
        cache.solver(laplace_jacobi(2), GRID, **KW)
        kw = dict(KW, bc=-3.0)
        cache.solver(laplace_jacobi(2), GRID, **kw)
        assert len(cache) == 1 and cache.stats.hits == 1

    def test_convergence_cfg_separates_entries(self):
        cache = _cache()
        cache.solver(laplace_jacobi(2), GRID, **KW)
        cache.solver(laplace_jacobi(2), GRID, **dict(KW, rtol=1e-6))
        assert len(cache) == 2 and cache.stats.misses == 2

    @pytest.mark.parametrize("kw", [
        dict(KW, bc=None),                         # raw application
        dict(KW, backend="dense", mode=BoundaryMode.MATRIX),
        dict(KW, bc=DirichletBC(np.full(GRID, 0.5, np.float32))),  # array BC
    ], ids=["bc-none", "dense-matrix", "array-bc"])
    def test_non_bucketable_degrades_to_exact(self, kw):
        cache = _cache()
        s = cache.solver(laplace_jacobi(2), GRID, **kw)
        assert not s.padded and s.bucket is None
        # still cached: the same request hits
        cache.solver(laplace_jacobi(2), GRID, **kw)
        assert cache.stats.hits == 1 and len(cache) == 1

    def test_oversized_pad_ratio_degrades_to_exact(self):
        # (17, 17) pads to (32, 32): ratio ~3.5 > 1.1 -> exact entry.
        cache = _cache(max_pad_ratio=1.1)
        s = cache.solver(laplace_jacobi(2), (17, 17), **KW)
        assert not s.padded
        cache.solver(laplace_jacobi(2), (17, 17), **KW)
        assert cache.stats.hits == 1


class TestExactness:
    """Padded executions must be indistinguishable from unpadded ones."""

    def _compare(self, spec, x0, x_atol=0.0, **kw):
        cache = _cache()
        cached = cache.solver(spec, x0.shape[-spec.ndim:], **kw)
        assert cached.padded, "exactness test must exercise the embedding"
        got = cached.solve(x0)
        want = solve(spec, x0, backend=cached.backend, **kw)
        if x_atol:
            np.testing.assert_allclose(
                np.asarray(got.x), np.asarray(want.x), atol=x_atol, rtol=0)
        else:
            assert np.array_equal(np.asarray(got.x), np.asarray(want.x))
        assert np.array_equal(np.asarray(got.iterations),
                              np.asarray(want.iterations))
        assert np.array_equal(np.asarray(got.converged),
                              np.asarray(want.converged))
        return got, want

    def test_bare_grid(self):
        got, want = self._compare(laplace_jacobi(2), _x0(GRID), **KW)
        assert got.converged and got.x.shape == GRID

    def test_batched_per_instance_iterations(self):
        # Instances converging at different times must freeze identically.
        x0 = np.stack([_x0(GRID, seed=s) for s in range(3)])
        x0[0] = 0.5  # already at the fixed point -> converges immediately
        got, want = self._compare(laplace_jacobi(2), x0, **KW)
        assert got.iterations[0] < got.iterations[1]

    def test_variable_coefficients(self):
        kappa = (1.0 + np.random.default_rng(3).random(GRID)
                 ).astype(np.float32)
        spec = heterogeneous_jacobi(kappa)
        # per-cell multiplies let XLA contract fma differently for the
        # bucket-shaped kernel: allow ulp-level drift on the field, but the
        # iteration counts and convergence decisions must still be identical
        got, _ = self._compare(spec, _x0(GRID, seed=1), x_atol=3e-7, **KW)
        assert got.converged

    def test_source_term(self):
        spec = laplace_jacobi(2)
        src = (np.random.default_rng(5).standard_normal(GRID) * 1e-2
               ).astype(np.float32)
        cache = _cache()
        cached = cache.solver(spec, GRID, **KW)
        got = cached.solve(_x0(GRID), source=src)
        want = solve(spec, _x0(GRID), backend=cached.backend, source=src, **KW)
        assert np.array_equal(np.asarray(got.x), np.asarray(want.x))
        assert got.iterations == want.iterations

    def test_one_shot_solve_entry_point(self):
        cache = _cache()
        got = cache.solve(laplace_jacobi(2), _x0(GRID), **KW)
        assert got.converged
        cache.solve(laplace_jacobi(2), _x0((14, 10), seed=2), **KW)
        assert cache.stats.hits == 1  # same bucket, no recompile


class TestLifecycle:
    def test_lru_eviction_order(self):
        cache = _cache(capacity=2)
        cache.solver(laplace_jacobi(2), (8, 8), **KW)      # bucket (8, 8)
        cache.solver(laplace_jacobi(2), (12, 12), **KW)    # bucket (16, 16)
        cache.solver(laplace_jacobi(2), (8, 8), **KW)      # touch (8, 8)
        cache.solver(laplace_jacobi(2), (20, 20), **KW)    # evicts (16, 16)
        assert len(cache) == 2 and cache.stats.evictions == 1
        buckets = [k[2] for k in cache.keys()]
        assert (8, 8) in buckets and (32, 32) in buckets
        # the evicted bucket misses again
        misses = cache.stats.misses
        cache.solver(laplace_jacobi(2), (12, 12), **KW)
        assert cache.stats.misses == misses + 1

    def test_corrupt_entry_rebuilds_once(self):
        cache = _cache()
        cached = cache.solver(laplace_jacobi(2), GRID, **KW)
        cache._entries[cached._entry.key].obj = None  # sabotage
        res = cached.solve(_x0(GRID))
        assert res.converged
        assert cache.stats.rebuilds == 1
        # the rebuilt entry serves subsequent calls without another rebuild
        assert cached.solve(_x0(GRID, seed=2)).converged
        assert cache.stats.rebuilds == 1

    def test_stats_shape(self):
        cache = _cache()
        cache.solver(laplace_jacobi(2), GRID, **KW)
        cache.solver(laplace_jacobi(2), GRID, **KW)
        d = cache.stats.as_dict()
        assert d["hits"] == 1 and d["misses"] == 1
        assert d["hit_rate"] == 0.5
        assert d["compile_seconds"] > 0.0

    def test_clear(self):
        cache = _cache()
        cache.solver(laplace_jacobi(2), GRID, **KW)
        cache.clear()
        assert len(cache) == 0

    def test_multigrid_entries_cache(self):
        cache = _cache()
        mg1 = cache.multigrid(laplace_jacobi(2), (17, 17), bc=0.0, rtol=1e-4)
        mg2 = cache.multigrid(laplace_jacobi(2), (17, 17), bc=0.0, rtol=1e-4)
        assert mg1 is mg2
        assert cache.stats.hits == 1
        res = mg1.solve(jnp.asarray(_x0((17, 17), bc=0.0)))
        assert res.converged

    def test_default_cache_swap(self):
        mine = _cache()
        old = set_default_plan_cache(mine)
        try:
            assert default_plan_cache() is mine
        finally:
            set_default_plan_cache(old)

    def test_probe_picks_a_capable_backend(self):
        # The measured-probe path must land on an operand-capable backend
        # and account its time.
        cache = PlanCache(probe=True, probe_iters=2)
        s = cache.solver(laplace_jacobi(2), (8, 8), **KW)
        assert s.backend in ("reference", "conv")
        assert cache.stats.probe_seconds > 0.0
        assert s.solve(_x0((8, 8))).converged


class TestConcurrency:
    def test_racing_threads_build_once(self, monkeypatch):
        cache = _cache()
        solvers, errors, builds = [], [], []

        orig = PlanCache._build_bucket

        def counting(self, *a, **kw):
            builds.append(threading.get_ident())
            return orig(self, *a, **kw)

        monkeypatch.setattr(PlanCache, "_build_bucket", counting)

        def work(seed):
            try:
                s = cache.solver(laplace_jacobi(2), GRID, **KW)
                solvers.append(s.solve(_x0(GRID, seed=seed)))
            except Exception as e:  # pragma: no cover - diagnostic
                errors.append(e)

        threads = [threading.Thread(target=work, args=(i,)) for i in range(6)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors
        assert len(solvers) == 6 and all(r.converged for r in solvers)
        assert len(cache) == 1
        assert len(builds) == 1  # the latch serialized construction
