#!/usr/bin/env bash
# CI entry point.
#
#   scripts/ci.sh          fast tier: tests minus the `slow` marker (full
#                          conformance matrix, subprocess multi-device runs)
#                          + the fast stencil benchmark
#   scripts/ci.sh --all    full tier: every test (matrix + solver +
#                          distributed) + the table1/fig6 benchmark sections
#
# Both tiers refresh BENCH_stencil.json (schema 3: us_per_call + solver +
# multigrid metrics) so the perf trajectory and the cost-model regression tests in
# tests/solver/test_cost_model.py stay anchored to this host.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--all" ]]; then
  echo "== full test suite (matrix + solver + distributed tiers) =="
  python -m pytest -x -q
  echo "== stencil benchmark (table1 + fig6 + multigrid) =="
  python -m benchmarks.run --only table1_2d fig6_3d multigrid --json BENCH_stencil.json
else
  echo "== fast test tier (-m 'not slow') =="
  python -m pytest -x -q -m "not slow"
  echo "== stencil benchmark (fast) =="
  python -m benchmarks.run --fast --only table1_2d multigrid --json BENCH_stencil.json
fi
