#!/usr/bin/env bash
# CI entry point.
#
#   scripts/ci.sh              fast tier: tests minus the `slow` marker (full
#                              conformance matrix, subprocess multi-device
#                              runs) + the fast stencil benchmark
#   scripts/ci.sh --all        full tier: every test (matrix + solver +
#                              distributed) + the table1/fig6 benchmark
#                              sections
#   scripts/ci.sh --tune-check validate the committed TUNED_stencil.json only
#                              (schema + every entry maps to a legal
#                              backend_support cell) and exit
#
# Both test tiers refresh BENCH_stencil.json (schema 4: us_per_call +
# interpreted_rows + solver + multigrid + autotune metrics) so the perf
# trajectory and the cost-model regression tests in
# tests/solver/test_cost_model.py stay anchored to this host, and both run
# the tune-check so a stale/illegal tuned table fails CI.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

tune_check() {
  echo "== tuned-table check (TUNED_stencil.json) =="
  python -m repro.core.autotune --check TUNED_stencil.json
}

if [[ "${1:-}" == "--tune-check" ]]; then
  tune_check
  exit 0
elif [[ "${1:-}" == "--all" ]]; then
  tune_check
  echo "== full test suite (matrix + solver + distributed tiers) =="
  python -m pytest -x -q
  echo "== stencil benchmark (table1 + fig6 + multigrid + autotune) =="
  python -m benchmarks.run --only table1_2d fig6_3d multigrid autotune --json BENCH_stencil.json
else
  tune_check
  echo "== fast test tier (-m 'not slow') =="
  python -m pytest -x -q -m "not slow"
  echo "== stencil benchmark (fast) =="
  python -m benchmarks.run --fast --only table1_2d multigrid autotune --json BENCH_stencil.json
fi
