#!/usr/bin/env bash
# CI entry point: tier-1 tests + the fast stencil benchmark with a
# machine-readable perf artifact (BENCH_stencil.json) for trajectory tracking.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== stencil benchmark (fast) =="
python -m benchmarks.run --fast --only table1_2d --json BENCH_stencil.json
