#!/usr/bin/env bash
# CI entry point.
#
#   scripts/ci.sh              fast tier: tests minus the `slow` marker (full
#                              conformance matrix, subprocess multi-device
#                              runs) + the fast stencil benchmark
#   scripts/ci.sh --all        full tier: every test (matrix + solver +
#                              distributed) + the table1/fig6 benchmark
#                              sections + the scaling smoke
#   scripts/ci.sh --tune-check validate the committed TUNED_stencil.json only
#                              (schema + every entry maps to a legal
#                              backend_support cell) and exit
#   scripts/ci.sh --scaling-smoke
#                              run the forced-8-host-device weak-scaling
#                              benchmark one row deep and validate the
#                              `scaling` section, then exit
#   scripts/ci.sh --adjoint-smoke
#                              run the differentiable-solve gate: the fast
#                              adjoint gradient tests plus the learned-
#                              stencil training example (must reach a 10x
#                              loss reduction with a checkpoint round-trip)
#   scripts/ci.sh --serve-smoke
#                              run the serving gate: plan-cache + engine
#                              tests, then the serving benchmark in smoke
#                              mode and its section validation (coalesced
#                              throughput must clear the 5x-vs-cold bar)
#
# Both test tiers refresh BENCH_stencil.json (schema 7: us_per_call +
# interpreted_rows + solver + multigrid + autotune + scaling + adjoint +
# serving; sections a run didn't produce are omitted, never written as {})
# so the perf trajectory and the cost-model regression tests in
# tests/solver/test_cost_model.py stay anchored to this host, and both run
# the tune-check so a stale/illegal tuned table fails CI.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

tune_check() {
  echo "== tuned-table check (TUNED_stencil.json) =="
  python -m repro.core.autotune --check TUNED_stencil.json
}

scaling_smoke() {
  echo "== scaling smoke (8 forced host devices, one weak row + fuse sweep) =="
  local out
  out="$(mktemp /tmp/BENCH_scaling_smoke.XXXXXX.json)"
  python -m benchmarks.scaling_bench --smoke --json "$out"
  python -m benchmarks.scaling_bench --validate "$out"
  rm -f "$out"
}

adjoint_smoke() {
  echo "== adjoint smoke (gradient checks + learned-stencil training) =="
  # Transpose algebra + structural gradient properties (the elementwise FD
  # sweeps stay in the normal test tiers; they dominate the runtime).
  python -m pytest -x -q tests/solver/test_adjoint.py \
    -k "Transpose or ForwardAgreement or Structure"
  python -m pytest -x -q tests/test_solver_layer.py
  python examples/learned_stencil.py --smoke --steps 80 --assert-decreasing
}

serve_smoke() {
  echo "== serving smoke (plan cache + coalescing engine + 5x acceptance) =="
  python -m pytest -x -q tests/serve
  local out
  out="$(mktemp /tmp/BENCH_serving_smoke.XXXXXX.json)"
  python -m benchmarks.serving_bench --smoke --json "$out"
  python -m benchmarks.serving_bench --validate "$out"
  rm -f "$out"
}

if [[ "${1:-}" == "--tune-check" ]]; then
  tune_check
  exit 0
elif [[ "${1:-}" == "--scaling-smoke" ]]; then
  scaling_smoke
  exit 0
elif [[ "${1:-}" == "--adjoint-smoke" ]]; then
  adjoint_smoke
  exit 0
elif [[ "${1:-}" == "--serve-smoke" ]]; then
  serve_smoke
  exit 0
elif [[ "${1:-}" == "--all" ]]; then
  tune_check
  echo "== full test suite (matrix + solver + distributed tiers) =="
  python -m pytest -x -q
  scaling_smoke
  adjoint_smoke
  serve_smoke
  echo "== stencil benchmark (table1 + fig6 + multigrid + autotune + scaling + adjoint + serving) =="
  python -m benchmarks.run --only table1_2d fig6_3d multigrid autotune scaling adjoint serving --json BENCH_stencil.json
else
  tune_check
  echo "== fast test tier (-m 'not slow') =="
  python -m pytest -x -q -m "not slow"
  echo "== stencil benchmark (fast) =="
  python -m benchmarks.run --fast --only table1_2d multigrid autotune adjoint serving --json BENCH_stencil.json
fi
